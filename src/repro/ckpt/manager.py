"""Atomic, async checkpoint/restore with auto-resume.

Fault-tolerance contract (the part the restart tests assert):
  * atomicity — state is staged into ``step_N.tmp-<nonce>`` and renamed to
    ``step_N`` only when fully written; a crash mid-write never corrupts the
    latest checkpoint, and half-written temp dirs are swept on restore;
  * async — ``save`` snapshots device arrays to host (blocking only on
    device_get) and writes on a background thread, keeping the train loop's
    critical path free;
  * auto-resume — ``restore_latest`` picks the newest *valid* step (a MANIFEST
    written last marks validity) so a job restarted after preemption continues
    from the last durable state;
  * retention — ``keep`` most recent checkpoints are retained, older ones GC'd.

Arrays are stored as raw .npy leaves under a pytree manifest; restoring
device-puts them against the current mesh's shardings — which may differ from
the saving mesh (elastic restart onto a different draft/target split or pod
count; runtime/elastic.py).
"""

from __future__ import annotations

import json
import os
import secrets
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, blocking: bool = False) -> None:
        """Snapshot ``state`` (a pytree of arrays) at ``step``."""
        leaves, treedef = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        if blocking:
            self._write(step, host)
        else:
            self.wait()
            self._thread = threading.Thread(target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves: list[np.ndarray]) -> None:
        with self._lock:
            final = os.path.join(self.dir, f"step_{step:012d}")
            tmp = f"{final}.tmp-{secrets.token_hex(4)}"
            os.makedirs(tmp, exist_ok=True)
            dtypes = []
            for i, arr in enumerate(host_leaves):
                dtypes.append(str(arr.dtype))
                if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
                    arr = arr.astype(np.float32)  # npy can't hold ml_dtypes
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            manifest = {"step": step, "n_leaves": len(host_leaves), "dtypes": dtypes}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"), ignore_errors=True)
        # sweep dead temp dirs
        for name in os.listdir(self.dir):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        steps = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_") or ".tmp-" in name:
                continue
            if os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore the pytree saved at ``step``.  ``like`` supplies the
        treedef; ``shardings`` (same structure) re-places leaves on device —
        possibly on a different mesh than the one that saved."""
        path = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        host = []
        for i in range(len(leaves)):
            arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
            want = manifest.get("dtypes", [None] * len(leaves))[i]
            if want and str(arr.dtype) != want and "bfloat16" in want:
                import ml_dtypes

                arr = arr.astype(ml_dtypes.bfloat16)
            host.append(arr)
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "device_set"))
            out = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
        else:
            out = [jax.device_put(h) for h in host]
        return jax.tree.unflatten(treedef, out)

    def restore_latest(self, like, shardings=None):
        """-> (step, state) from the newest valid checkpoint, or (None, None)."""
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
