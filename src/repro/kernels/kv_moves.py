"""Fused tree-aware KV-reorganization kernels (paper §3.2 + §3.3).

Every speculative round reorganizes the KV caches twice: the target cache
compacts the accepted tree rows into the prefix after verification, and the
draft cache re-roots onto the accepted path.  Both are row *moves* — M ≈ bs
rows out of an S_max-row cache — yet the XLA formulation (one-hot einsum
gather + scatter, models/attention.py) reads and rewrites the entire
[B, S, F] cache twice per layer stack, O(B·S·F) HBM traffic that grows with
context length instead of tree size.

``kv_move_rows_pallas`` replaces that with a single launch gridded over
(layer-stack U, batch B).  The cache stays a full-array HBM ref
(``memory_space=ANY``); the kernel DMAs the M source rows into a VMEM stage,
waits, then DMAs them back out to their destinations — a gather-all /
scatter-all barrier that gives parallel-assignment semantics for overlapping
src/dst windows (the compaction shift case) by construction.  HBM traffic is
O(B·M·F) touched rows.

Two variants, selected by ``donate``:

  donate=True   the output aliases the input (``input_output_aliases``); the
                move is in place.  Only safe when the caller owns the buffer
                (the jit wrapping it donates the cache argument).
  donate=False  the kernel first DMAs the whole (u, b) slab input→output and
                only then scatters the staged rows into the *output* — the
                input ref is never written.  This is the speculative
                lookahead variant: the async pipeline's rollback contract
                (kv.py docstring) keeps the pre-reroot cache alive as the
                reconcile fallback, so the re-root must not mutate it.

``slot_write_rows_pallas`` is the slot-lifecycle sibling: one launch that
DMAs batch row 0 of a donor cache into batch row ``slot`` of every serving
cache leaf (admission install, or retire-time zeroing via an all-zeros
donor), replacing the per-leaf ``.at[].set`` dispatch storm with a single
kernel whose cost is one cache row per leaf.

Index maps, aliasing rules, and the snapshot/no-donation contract for every
kernel in this package are catalogued in docs/kernels.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import ANY_SPACE, CompilerParams


# -----------------------------------------------------------------------------
# kv_move_rows — O(M) row moves on one [U, B, S, F] cache leaf
# -----------------------------------------------------------------------------


def _kv_move_kernel(src_ref, dst_ref, act_ref, cache_ref, out_ref,
                    stage, gsem, ssem, csem, *, copy_through: bool):
    """One (u, b) grid cell: move rows src[b, m] -> dst[b, m] where active.

    src_ref/dst_ref/act_ref are scalar-prefetch [B, M] i32; cache_ref/out_ref
    are full-array HBM refs [U, B, S, F] (out aliases cache when the caller
    donates).  All gathers complete before any scatter starts, so an
    overlapping move plan behaves as a parallel assignment.
    """
    u, b = pl.program_id(0), pl.program_id(1)
    M = src_ref.shape[1]

    def gather(m):
        return pltpu.make_async_copy(
            cache_ref.at[u, b, pl.ds(src_ref[b, m], 1)],
            stage.at[pl.ds(m, 1)], gsem.at[m])

    def scatter(m):
        return pltpu.make_async_copy(
            stage.at[pl.ds(m, 1)],
            out_ref.at[u, b, pl.ds(dst_ref[b, m], 1)], ssem.at[m])

    if copy_through:
        # snapshot-preserving variant: land the untouched slab in the output
        # first; the staged rows then overwrite only their destinations there
        pltpu.make_async_copy(cache_ref.at[u, b], out_ref.at[u, b], csem).start()
    for m in range(M):

        @pl.when(act_ref[b, m] != 0)
        def _(m=m):
            gather(m).start()

    if copy_through:
        pltpu.make_async_copy(cache_ref.at[u, b], out_ref.at[u, b], csem).wait()
    for m in range(M):

        @pl.when(act_ref[b, m] != 0)
        def _(m=m):
            gather(m).wait()

    # barrier passed: every source row is staged in VMEM; writes may begin
    for m in range(M):

        @pl.when(act_ref[b, m] != 0)
        def _(m=m):
            scatter(m).start()

    for m in range(M):

        @pl.when(act_ref[b, m] != 0)
        def _(m=m):
            scatter(m).wait()


def kv_move_rows_pallas(arr, src, dst, active, *, donate: bool, interpret: bool = True):
    """arr: [U, B, S, F]; src/dst/active: i32 [B, M] with active ∈ {0, 1}.

    Returns arr with rows moved (active: out[u, b, dst] = arr[u, b, src],
    parallel-assignment semantics).  ``donate=True`` aliases output to input
    (in-place; caller must own the buffer); ``donate=False`` never writes the
    input ref.  HBM traffic per (u, b): M·F gather + M·F scatter (+ one S·F
    pass-through copy for the non-donating variant).
    """
    if arr.ndim != 4:
        raise ValueError(f"arr must be [U, B, S, F], got shape {arr.shape}")
    U, B, S, F = arr.shape
    M = src.shape[1]
    if src.shape != (B, M) or dst.shape != (B, M) or active.shape != (B, M):
        raise ValueError(
            f"src/dst/active must all be [B={B}, M]: "
            f"{src.shape} / {dst.shape} / {active.shape}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(U, B),
        in_specs=[pl.BlockSpec(memory_space=ANY_SPACE)],
        out_specs=pl.BlockSpec(memory_space=ANY_SPACE),
        scratch_shapes=[
            pltpu.VMEM((M, F), arr.dtype),  # row stage
            pltpu.SemaphoreType.DMA((M,)),  # gather sems
            pltpu.SemaphoreType.DMA((M,)),  # scatter sems
            pltpu.SemaphoreType.DMA(()),  # pass-through copy sem
        ],
    )
    kwargs = {}
    if donate:
        # alias indices count the scalar-prefetch args: cache is operand 3
        kwargs["input_output_aliases"] = {3: 0}
    return pl.pallas_call(
        functools.partial(_kv_move_kernel, copy_through=not donate),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arr.shape, arr.dtype),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        **kwargs,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), active.astype(jnp.int32), arr)


# -----------------------------------------------------------------------------
# slot_write_rows — one launch for the whole-slot install / zero lifecycle
# -----------------------------------------------------------------------------


def _slot_write_kernel(n_leaves, slot_ref, *refs):
    """refs: donor_0..L-1, cache_0..L-1, out_0..L-1 (aliased to cache), sem.

    DMAs donor[:, 0] -> out[:, slot] for every leaf in one kernel; starts
    all copies before waiting so the per-leaf transfers overlap.
    """
    L = n_leaves
    donors = refs[:L]
    outs = refs[2 * L:3 * L]
    sem = refs[3 * L]
    slot = slot_ref[0]
    copies = [
        pltpu.make_async_copy(donors[i].at[:, 0], outs[i].at[:, slot], sem.at[i])
        for i in range(L)
    ]
    for c in copies:
        c.start()
    for c in copies:
        c.wait()


def slot_write_rows_pallas(cache_leaves, donor_leaves, slot, *, interpret: bool = True):
    """Write batch row 0 of every donor leaf into batch row ``slot`` of the
    matching cache leaf, in one launch.

    cache_leaves[i]: [U_i, B, ...]; donor_leaves[i]: [U_i, 1, ...] with
    identical dtype and non-batch dims.  ``slot`` may be a traced scalar.
    The outputs alias the cache leaves (in-place; the wrapping jit donates
    the cache).  Returns the list of updated leaves.
    """
    L = len(cache_leaves)
    if L == 0 or len(donor_leaves) != L:
        raise ValueError(f"leaf lists must be equal and non-empty: {L} vs {len(donor_leaves)}")
    for big, one in zip(cache_leaves, donor_leaves):
        if big.ndim < 2 or one.shape != (big.shape[0], 1) + big.shape[2:]:
            raise ValueError(f"donor leaf {one.shape} does not match cache leaf {big.shape}")
        if big.dtype != one.dtype:
            raise ValueError(f"dtype mismatch: cache {big.dtype} vs donor {one.dtype}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=ANY_SPACE)] * (2 * L),
        out_specs=[pl.BlockSpec(memory_space=ANY_SPACE)] * L,
        scratch_shapes=[pltpu.SemaphoreType.DMA((L,))],
    )
    return pl.pallas_call(
        functools.partial(_slot_write_kernel, L),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(c.shape, c.dtype) for c in cache_leaves],
        # operand layout: slot (scalar prefetch), L donors, L caches —
        # cache i is operand 1 + L + i, aliased in place onto output i
        input_output_aliases={1 + L + i: i for i in range(L)},
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.reshape(jnp.asarray(slot, jnp.int32), (1,)), *donor_leaves, *cache_leaves)
