"""Latency-optimized Pallas kernels (paper §3.3), TPU-adapted.

  tree_attention    — non-square tree-mask attention (draft + verify + decode)
  decode_attention  — split-KV decode, in-kernel combine (1 launch, 0 barriers)
  fused_swiglu      — silu(xW) ⊙ (xV) in one HBM pass over x
  int4_matmul       — AWQ groupwise int4 dequant-GEMM

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles the
tests sweep against.
"""
