"""Pure-jnp oracles for every Pallas kernel (the allclose reference in tests).

Shapes follow the kernel contracts in ops.py.  These are deliberately naive —
materialized scores, full masks, f32 math — so they are easy to audit against
the paper's operator definitions (§3.3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_attention_ref(q, k, v, mask):
    """Non-square tree-masked attention (paper §3.1/§3.3).

    q: [B, n, Hq, hd] draft-leaf / verification queries
    k, v: [B, S, Hkv, hd] full cache (prefix + tree regions)
    mask: bool [B, n, S] — True = attend (prefix + tree ancestors + self)
    Returns [B, n, Hq, hd]. Fully-masked query rows return zeros.
    """
    B, n, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(B, n, hkv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bnkgh,bskh->bkgns", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd)
    m = mask[:, None, None, :, :]
    scores = jnp.where(m, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.any(m, axis=-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bkgns,bskh->bnkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, n, hq, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, length):
    """Single-position decode attention with a length mask (split-KV oracle).

    q: [B, Hq, hd]; k, v: [B, S, Hkv, hd]; length: i32[B] valid cache rows.
    Returns [B, Hq, hd].
    """
    B, hq, hd = q.shape
    S, hkv = k.shape[1], k.shape[2]
    mask = jnp.arange(S)[None, :] < length[:, None]  # [B, S]
    out = tree_attention_ref(q[:, None], k, v, mask[:, None, :])
    return out[:, 0]


def fused_swiglu_ref(x, wg, wu, bg=None, bu=None):
    """SwiGLU gate: silu(x@wg + bg) * (x@wu + bu).  x: [T, d] -> [T, ff]."""
    g = x.astype(jnp.float32) @ wg.astype(jnp.float32)
    u = x.astype(jnp.float32) @ wu.astype(jnp.float32)
    if bg is not None:
        g = g + bg.astype(jnp.float32)
    if bu is not None:
        u = u + bu.astype(jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)


def kv_move_rows_ref(arr, src, dst, mask):
    """Index-based KV row moves — oracle AND the CPU/interpret production
    fallback for ``kv_move_rows_pallas`` (paper §3.2 reorganization).

    arr: [U, B, S, ...] cache leaf; src/dst: i32 [B, M]; mask: bool [B, M].
    Moves arr[u, b, src[b, m]] -> arr[u, b, dst[b, m]] where the combined
    mask (mask & src >= 0 & dst >= 0) holds, as a parallel assignment: all
    sources are read from the pre-move array before any write.

    Unlike the retired one-hot einsum formulation this gathers only the M
    plan rows (masked-off entries clamp to row 0 and are dropped at the
    scatter via an out-of-bounds index), never the full cache — O(B·M·F)
    work instead of two dense O(B·S·F) passes.  Active destinations are
    distinct by MovePlan construction; duplicate destinations among masked
    rows all map to the dropped index S.
    """
    U, B, S = arr.shape[:3]
    act = mask & (src >= 0) & (dst >= 0)
    flat = arr.reshape(U, B, S, -1)
    rows = jnp.take_along_axis(flat, jnp.where(act, src, 0)[None, :, :, None], axis=2)
    didx = jnp.where(act, dst, S)  # S = out of bounds -> dropped
    out = flat.at[:, jnp.arange(B)[:, None], didx].set(rows, mode="drop")
    return out.reshape(arr.shape)


def int4_matmul_ref(x, qweight, scales, zeros, group_size: int):
    """AWQ groupwise int4 dequant-GEMM oracle.

    x: [T, K]; qweight: int8 [K, N] holding values in [0, 15];
    scales, zeros: [K // group_size, N].  w = (q - z) * s.  Returns [T, N].
    """
    K, N = qweight.shape
    s = jnp.repeat(scales, group_size, axis=0)
    z = jnp.repeat(zeros, group_size, axis=0)
    w = (qweight.astype(jnp.float32) - z.astype(jnp.float32)) * s.astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
