"""Tree-masked attention Pallas kernel (paper §3.1 non-square mask, §3.3).

One kernel serves draft expansion (w leaves vs prefix+tree), target
verification (bs nodes vs prefix+subgraph) and plain decode (n=1, causal
mask) — the paper's masked-attention operator with a general [n, S] mask.

TPU adaptation (DESIGN.md §3): the GPU kernel splits KV across threadblocks
and combines partial (max, sum, acc) via the NCCL-LL flag protocol; here the
KV split is the *sequential minor grid dimension* — running max / sum / acc
accumulators live in VMEM scratch across KV-block steps, so the combine needs
no barrier and no second kernel launch at all.

Layout: grid (B, Hkv, S/bk); every (b, h) step streams K/V tiles
[bk, hd] and the mask tile [n, bk] HBM→VMEM while the [G·n, hd] query block
stays resident.  All matmul tiles are 128-aligned (ops.py pads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_s, l_s, acc_s, *, g: int, scale: float):
    """Grid step (b, h, s): one KV tile against the resident query block.

    q_ref   [1, 1, Gn, hd]  (g-major: row g*n + i is group g of query i)
    k_ref   [1, bk, 1, hd]
    v_ref   [1, bk, 1, hd]
    mask_ref[1, n, bk]
    o_ref   [1, 1, Gn, hd]
    scratch m_s/l_s [Gn, 128] f32, acc_s [Gn, hd] f32
    """
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)  # [Gn, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)  # [bk, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)  # [bk, hd]
    n, bk = mask_ref.shape[1], mask_ref.shape[2]
    gn = q.shape[0]
    mask = jnp.broadcast_to(mask_ref[0][None], (g, n, bk)).reshape(gn, bk)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [Gn, bk]
    scores = jnp.where(mask, scores, NEG)

    m_prev = m_s[:, :1]  # [Gn, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    # fully-masked tiles keep m at NEG; p must be zero there, not exp(0)
    p = jnp.exp(scores - m_new) * mask  # [Gn, bk]
    alpha = jnp.exp(m_prev - m_new)  # [Gn, 1]
    l_new = l_s[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
    l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(s == pl.num_programs(2) - 1)
    def _finish():
        l = l_s[:, :1]
        out = acc_s[...] / jnp.where(l > 0, l, 1.0)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def tree_attention_pallas(q_r, k, v, mask, *, scale: float, block_k: int, interpret: bool):
    """q_r: [B, Hkv, Gn, hd] g-major; k/v: [B, S, Hkv, hd]; mask: [B, n, S].

    Shapes must be pre-padded: S % block_k == 0, hd/Gn MXU-aligned.
    ``scale`` is 1/sqrt(true head_dim) — hd here may be padded.
    Returns [B, Hkv, Gn, hd].
    """
    B, hkv, gn, hd = q_r.shape
    S = k.shape[1]
    n = mask.shape[1]
    if S % block_k or gn % n:
        raise ValueError(
            f"tree_attention: S={S} must be a multiple of block_k={block_k} "
            f"and Gn={gn} of n={n} — the floor-div grid would silently drop "
            f"the remainder (pad via kernels.ops)")
    g = gn // n
    grid = (B, hkv, S // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, g=g, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gn, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, n, block_k), lambda b, h, s: (b, 0, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, gn, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hkv, gn, hd), q_r.dtype),
        scratch_shapes=[
            pltpu.VMEM((gn, 128), jnp.float32),
            pltpu.VMEM((gn, 128), jnp.float32),
            pltpu.VMEM((gn, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_r, k, v, mask)
