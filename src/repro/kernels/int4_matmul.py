"""AWQ groupwise int4 dequant-GEMM Pallas kernel (paper §5.1 serving precision).

Weights are packed two 4-bit values per int8 byte along K (quant/awq.py); the
kernel streams packed tiles HBM→VMEM — half the weight bandwidth of int8, a
quarter of bf16, which is the entire point at decode batch sizes ≤ 16 where
GEMMs are memory-bound — unpacks nibbles and applies the groupwise
``(q - z) * s`` dequant in VMEM, then runs the MXU matmul in f32.

Block constraint: block_k == group_size, so each K step touches exactly one
scale/zero row (no intra-tile group boundary).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(x_ref, qw_ref, s_ref, z_ref, o_ref, acc_s):
    """Grid step (i, j, k).

    x_ref [bm, bk]; qw_ref [bk//2, bn] packed int8; s_ref/z_ref [1, bn]
    (block_k == group_size); acc [bm, bn] f32.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)

    packed = qw_ref[...]  # [bk//2, bn] int8: low nibble = even k, high = odd k
    lo = (packed & 0xF).astype(jnp.float32)
    hi = ((packed >> 4) & 0xF).astype(jnp.float32)
    bk2, bn = packed.shape
    w = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)  # interleave along K
    w = (w - z_ref[...].astype(jnp.float32)) * s_ref[...].astype(jnp.float32)

    acc_s[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = acc_s[...].astype(o_ref.dtype)


def int4_matmul_pallas(x, qweight, scales, zeros, *, group_size: int,
                       block_m: int, block_n: int, interpret: bool):
    """x: [M, K]; qweight: int8 [K//2, N] packed; scales/zeros: [K//g, N].

    block_k is pinned to ``group_size``; shapes pre-padded to block multiples.
    Returns [M, N] in x.dtype.
    """
    M, K2 = x.shape[0], qweight.shape[0]
    K = K2 * 2
    N = qweight.shape[1]
    assert K % group_size == 0
    grid = (M // block_m, N // block_n, K // group_size)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, group_size), lambda i, j, k: (i, k)),
            pl.BlockSpec((group_size // 2, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, qweight, scales, zeros)
