"""Fused SwiGLU Pallas kernel (paper §3.3 "Fused SwiGLU").

SwiGLU(x, W, V) = silu(xW) ⊙ (xV).  The paper's GPU kernel computes the same
tile of both matmuls in one threadblock so x is loaded from HBM once and the
σ·⊙ epilogue runs before the store; here each (i, j) grid cell streams x and
the matching W / V tiles HBM→VMEM, accumulates BOTH products in f32 VMEM
scratch over the sequential K dimension, and applies silu(g)·u in-register on
the last K step — x read once, no intermediate HBM round-trip, one launch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(x_ref, wg_ref, wu_ref, o_ref, g_s, u_s):
    """Grid step (i, j, k): x tile [bm, bk] against wg/wu tiles [bk, bn]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        g_s[...] = jnp.zeros_like(g_s)
        u_s[...] = jnp.zeros_like(u_s)

    x = x_ref[...]
    g_s[...] += jax.lax.dot_general(
        x, wg_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    u_s[...] += jax.lax.dot_general(
        x, wu_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        g = g_s[...]
        o_ref[...] = (g * jax.nn.sigmoid(g) * u_s[...]).astype(o_ref.dtype)


def fused_swiglu_pallas(x, wg, wu, *, block_m: int, block_n: int, block_k: int, interpret: bool):
    """x: [M, K]; wg, wu: [K, N] — pre-padded to block multiples.

    Returns silu(x@wg) * (x@wu), [M, N].
    """
    M, K = x.shape
    N = wg.shape[1]
    if M % block_m or N % block_n or K % block_k:
        raise ValueError(
            f"fused_swiglu: shapes M={M}, N={N}, K={K} must be multiples of "
            f"blocks ({block_m}, {block_n}, {block_k}) — the floor-div grid "
            f"would silently drop the remainder (pad via kernels.ops)")
    grid = (M // block_m, N // block_n, K // block_k)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, block_n), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, wg, wu)
