"""Jit'd public wrappers around the Pallas kernels.

Each wrapper pads inputs to MXU-aligned block multiples (128 lanes, 8
sublanes), lays tensors out for the kernel grid, and un-pads the result.
Padding is semantics-preserving: padded KV rows are masked False, padded
matmul K columns are zero, padded query rows are sliced off.

``interpret=True`` (the default through flags.pallas_interpret on this CPU
container) runs the kernel bodies in Python for correctness validation; on a
real TPU the same calls compile to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.flags import get_flags
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.fused_swiglu import fused_swiglu_pallas
from repro.kernels.int4_matmul import int4_matmul_pallas
from repro.kernels.kv_moves import kv_move_rows_pallas, slot_write_rows_pallas
from repro.kernels.ref import kv_move_rows_ref
from repro.kernels.tree_attention import tree_attention_pallas


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_dim(x, axis: int, to: int):
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# -----------------------------------------------------------------------------
# tree attention
# -----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def tree_attention(q, k, v, mask, *, block_k: int = 128, interpret: bool = True):
    """q: [B, n, Hq, hd]; k, v: [B, S, Hkv, hd]; mask: bool [B, n, S].

    The paper's non-square tree-masked attention; returns [B, n, Hq, hd].
    """
    B, n, hq, hd = q.shape
    S, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)

    hd_p = _ceil_to(hd, 128)
    S_p = _ceil_to(S, block_k)
    n_p = _ceil_to(n, 8)

    qp = _pad_dim(_pad_dim(q, 3, hd_p), 1, n_p)
    kp = _pad_dim(_pad_dim(k, 3, hd_p), 1, S_p)
    vp = _pad_dim(_pad_dim(v, 3, hd_p), 1, S_p)
    mp = _pad_dim(_pad_dim(mask, 2, S_p), 1, n_p)

    # g-major query layout: [B, Hkv, G*n_p, hd]
    q_r = qp.reshape(B, n_p, hkv, g, hd_p).transpose(0, 2, 3, 1, 4).reshape(B, hkv, g * n_p, hd_p)

    out = tree_attention_pallas(q_r, kp, vp, mp, scale=scale, block_k=block_k, interpret=interpret)
    out = out.reshape(B, hkv, g, n_p, hd_p).transpose(0, 3, 1, 2, 4).reshape(B, n_p, hq, hd_p)
    return out[:, :n, :, :hd]


# -----------------------------------------------------------------------------
# decode attention (split-KV, fused combine)
# -----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, length, *, block_k: int = 128, interpret: bool = True):
    """q: [B, Hq, hd]; k, v: [B, S, Hkv, hd]; length: i32 [B].

    One-position decode against rows [0, length); returns [B, Hq, hd].
    """
    B, hq, hd = q.shape
    S, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)

    hd_p = _ceil_to(hd, 128)
    g_p = _ceil_to(g, 8)
    S_p = _ceil_to(S, block_k)

    qp = _pad_dim(q, 2, hd_p).reshape(B, hkv, g, hd_p)
    qp = _pad_dim(qp, 2, g_p)
    kp = _pad_dim(_pad_dim(k, 3, hd_p), 1, S_p)
    vp = _pad_dim(_pad_dim(v, 3, hd_p), 1, S_p)

    out = decode_attention_pallas(
        qp, kp, vp, length.reshape(B, 1).astype(jnp.int32),
        scale=scale, block_k=block_k, interpret=interpret,
    )
    return out[:, :, :g, :hd].reshape(B, hq, hd)


# -----------------------------------------------------------------------------
# fused SwiGLU
# -----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_swiglu(x, wg, wu, *, interpret: bool = True):
    """x: [T, d]; wg, wu: [d, ff] -> silu(x@wg) * (x@wu), [T, ff]."""
    T, K = x.shape
    N = wg.shape[1]
    bm = 8 if T <= 64 else 128
    bn, bk = 128, 128
    T_p, K_p, N_p = _ceil_to(T, bm), _ceil_to(K, bk), _ceil_to(N, bn)

    xp = _pad_dim(_pad_dim(x, 0, T_p), 1, K_p)
    wgp = _pad_dim(_pad_dim(wg, 0, K_p), 1, N_p)
    wup = _pad_dim(_pad_dim(wu, 0, K_p), 1, N_p)
    out = fused_swiglu_pallas(xp, wgp, wup, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return out[:T, :N]


# -----------------------------------------------------------------------------
# KV-reorganization row moves (cache compaction / re-root, paper §3.2)
# -----------------------------------------------------------------------------
# Unlike the kernels above these are NOT separately jitted: they are only
# ever called inside the engine's already-jitted round programs, and the
# fused/reference choice is a trace-time flag (use_pallas_kv_moves) exactly
# like the attention kernel selection in models/attention.py.


def kv_move_rows(arr, src, dst, mask, *, donate: bool = False):
    """Move rows of one cache leaf: arr [U, B, S, ...]; src/dst i32 [B, M];
    mask bool [B, M].  Parallel-assignment semantics (sources read before any
    write); entries with mask False, src < 0, or dst < 0 are dropped.

    ``donate=True`` may update in place (the fused kernel aliases its output
    onto the input) — callers must own the buffer, i.e. the wrapping jit
    donates the cache.  ``donate=False`` never mutates the input: the
    speculative-lookahead contract (kv.py) requires the retained pre-reroot
    snapshot to survive this call.
    """
    flags = get_flags()
    M = src.shape[1]
    if M == 0:
        return arr
    if flags.use_pallas_kv_moves:
        U, B, S = arr.shape[:3]
        active = (mask & (src >= 0) & (dst >= 0)).astype(jnp.int32)
        out = kv_move_rows_pallas(
            arr.reshape(U, B, S, -1), src, dst, active,
            donate=donate, interpret=flags.pallas_interpret)
        return out.reshape(arr.shape)
    return kv_move_rows_ref(arr, src, dst, mask)


def slot_write_rows(cache_leaves, donor_leaves, slot):
    """Fused slot lifecycle write: donor[:, 0] -> cache[:, slot] for every
    leaf in ONE kernel launch (vs one XLA update per leaf).  Returns the
    updated leaves, or None when the leaves don't fit the kernel's contract
    (shape/dtype mismatch, empty tree) — callers fall back to the per-leaf
    XLA path, which is also the flag-off default."""
    flags = get_flags()
    if not flags.use_pallas_kv_moves or not cache_leaves:
        return None
    if len(cache_leaves) != len(donor_leaves):
        return None
    for big, one in zip(cache_leaves, donor_leaves):
        if big.ndim < 2 or one.shape != (big.shape[0], 1) + big.shape[2:]:
            return None
        if big.dtype != one.dtype:
            return None
    return slot_write_rows_pallas(
        cache_leaves, donor_leaves, slot, interpret=flags.pallas_interpret)


# -----------------------------------------------------------------------------
# int4 AWQ dequant-GEMM
# -----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("group_size", "interpret"))
def int4_matmul(x, qweight, scales, zeros, *, group_size: int = 128, interpret: bool = True):
    """x: [T, K]; qweight: int8 [K//2, N] (packed pairs along K);
    scales/zeros: [K//group_size, N].  Returns [T, N] in x.dtype.

    K must already be a multiple of group_size (quantization granularity).
    """
    T, K = x.shape
    N = qweight.shape[1]
    assert K % group_size == 0 and qweight.shape[0] * 2 == K
    bm = 8 if T <= 64 else 128
    bn = 128
    T_p, N_p = _ceil_to(T, bm), _ceil_to(N, bn)

    xp = _pad_dim(x, 0, T_p)
    qwp = _pad_dim(qweight, 1, N_p)
    sp = _pad_dim(scales, 1, N_p)
    zp = _pad_dim(zeros, 1, N_p)
    out = int4_matmul_pallas(
        xp, qwp, sp, zp, group_size=group_size, block_m=bm, block_n=bn, interpret=interpret
    )
    return out[:T, :N]
