"""Split-KV decode attention, single kernel (paper §3.3 "Masked attention").

FlashAttention's decode path on GPU launches ``flash_fwd_splitkv_kernel`` to
let threadblocks share one KV head, then ``..._combine_kernel`` to reduce the
partial (max, sum, acc) triples; the paper fuses the two with an NCCL-LL
in-kernel barrier.  On TPU the split index IS the sequential minor grid
dimension: partial triples accumulate in VMEM scratch across splits, so the
reduction happens in-kernel with zero barriers and one launch — the same
insight, realized through the TPU grid model instead of flag polling.

Differences from tree_attention: queries are one position per sequence, the
mask is implicit (rows < length, read from SMEM), and RoPE for the single new
position is fused into the kernel (the paper fuses position embedding too).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, block_k: int, scale: float):
    """Grid step (b, h, s): KV split s of head h, sequence b.

    len_ref [1, 1] SMEM; q_ref [1, 1, Gn, hd]; k/v_ref [1, bk, 1, hd];
    o_ref [1, 1, Gn, hd]; scratch m/l [Gn, 128], acc [Gn, hd] (f32).
    """
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32)  # [Gn, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)  # [bk, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    gn = q.shape[0]

    rows = s * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = jnp.broadcast_to(rows < length, (gn, block_k))

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    scores = jnp.where(mask, scores, NEG)

    m_prev = m_s[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new) * mask
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_s[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
    l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(s == pl.num_programs(2) - 1)
    def _finish():
        l = l_s[:, :1]
        out = acc_s[...] / jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = jnp.where(l > 0, out, 0.0).astype(o_ref.dtype)


def decode_attention_pallas(q_r, k, v, length, *, scale: float, block_k: int, interpret: bool):
    """q_r: [B, Hkv, G, hd]; k/v: [B, S, Hkv, hd]; length: i32 [B, 1].

    Pre-padded shapes (S % block_k == 0).  Returns [B, Hkv, G, hd].
    """
    B, hkv, g, hd = q_r.shape
    S = k.shape[1]
    if S % block_k:
        raise ValueError(
            f"decode_attention: S={S} must be a multiple of block_k="
            f"{block_k} — the floor-div grid would silently drop the "
            f"remainder (pad via kernels.ops)")
    grid = (B, hkv, S // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hkv, g, hd), q_r.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(length, q_r, k, v)
