"""jax-version compat for the Pallas kernels.

``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` in newer jax;
every kernel imports the alias from here so the next rename lands in one
place.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
