"""jax-version compat for the Pallas kernels.

``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` in newer jax;
every kernel imports the alias from here so the next rename lands in one
place.  Same story for the un-blocked HBM memory space (``pltpu.ANY`` →
``pltpu.MemorySpace.ANY``) used by the manual-DMA kernels in kv_moves.py.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# full-array HBM refs (no automatic HBM<->VMEM block copies; the kernel
# issues its own DMAs).  pltpu.ANY on jax<=0.4.x, MemorySpace.ANY later.
ANY_SPACE = getattr(pltpu, "ANY", None)
if ANY_SPACE is None:  # pragma: no cover - newer jax
    ANY_SPACE = pltpu.MemorySpace.ANY
