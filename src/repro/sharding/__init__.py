from .rules import (
    Param,
    shard_map,
    DEFAULT_RULES,
    axes_of,
    add_leading_axis,
    constrain,
    get_mesh,
    set_mesh,
    use_mesh,
    spec_for,
    sharding_for_tree,
    unbox,
)

__all__ = [
    "Param",
    "shard_map",
    "DEFAULT_RULES",
    "axes_of",
    "add_leading_axis",
    "constrain",
    "get_mesh",
    "set_mesh",
    "use_mesh",
    "spec_for",
    "sharding_for_tree",
    "unbox",
]
