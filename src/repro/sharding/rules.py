"""Logical-axis sharding rules and the mesh context.

Every parameter in the model zoo is created as a ``Param(value, axes)`` where
``axes`` names each dimension with a *logical* axis ("embed", "heads", "ff",
...).  ``spec_for`` maps logical axes onto mesh axes through a rules table,
falling back to replication whenever a dimension is not divisible by the mesh
axis it would shard over (this is what makes every config lower on every mesh
without per-arch special cases).

Mesh axes used throughout:
  "model" — tensor parallelism inside an ICI domain
  "data"  — FSDP parameter/optimizer sharding + batch data parallelism
  "pod"   — data parallelism across pods (DCN); params replicated per pod
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax-version compat: shard_map graduated from jax.experimental to
# jax.shard_map, and its replication-check kwarg was renamed
# check_rep -> check_vma along the way.  All repo code calls
# repro.sharding.shard_map with the NEW spelling; this shim routes to
# whatever the installed jax provides.
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

import inspect as _inspect

_SHARD_MAP_PARAMS = frozenset(_inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *args, **kwargs):
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map_impl(f, *args, **kwargs)

# -----------------------------------------------------------------------------
# Param: an array boxed with its logical axis names (single source of truth).
# -----------------------------------------------------------------------------


@dataclasses.dataclass
class Param:
    value: Any
    axes: tuple

    def __repr__(self):  # pragma: no cover - debugging aid
        shp = getattr(self.value, "shape", None)
        return f"Param(shape={shp}, axes={self.axes})"


def _param_flatten(p: Param):
    return (p.value,), p.axes


def _param_unflatten(axes, children):
    return Param(children[0], axes)


jax.tree_util.register_pytree_node(Param, _param_flatten, _param_unflatten)


def unbox(tree):
    """Param tree -> plain array tree."""
    return jax.tree.map(lambda p: p.value, tree, is_leaf=lambda x: isinstance(x, Param))


def axes_of(tree):
    """Param tree -> logical-axes tree (same structure as ``unbox``)."""
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=lambda x: isinstance(x, Param))


def add_leading_axis(tree, name: str):
    """Prepend a logical axis to every Param (after vmapped/stacked init)."""
    return jax.tree.map(
        lambda p: Param(p.value, (name,) + tuple(p.axes)),
        tree,
        is_leaf=lambda x: isinstance(x, Param),
    )


# -----------------------------------------------------------------------------
# Logical -> mesh axis rules.
# -----------------------------------------------------------------------------

DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": "model",  # sequence parallelism (flags.seq_shard_acts)
    "act_embed": None,
    # weights: FSDP ("data") on the large replicated dim, TP ("model") on the
    # split dim.  "pod" never shards weights (DCN all-gather too slow).
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": None,  # experts replicated; ff-within-expert sharded (TP-in-expert)
    "experts_ep": "model",  # expert-parallel alternative (hillclimb)
    "inner": "model",  # ssm / rwkv inner dim
    "state": None,
    "conv": None,
    "lora": None,
    "unit": None,
    "layers": None,
    # caches
    "kv_seq": "model",  # decode-time KV cache sequence sharding
    "cache_batch": ("pod", "data"),
    None: None,
}


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape.get(n, 1)
    return size


def spec_for(mesh: Mesh, axes, shape, rules=None) -> P:
    """Build a PartitionSpec for ``shape`` whose dims carry logical ``axes``.

    Falls back to replication per-dim when the mesh axis is absent or does not
    divide the dim.  Guarantees no mesh axis is used twice in one spec.
    """
    rules = rules or DEFAULT_RULES
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        target = rules.get(ax)
        if target is None:
            out.append(None)
            continue
        names = (target,) if isinstance(target, str) else tuple(target)
        names = tuple(n for n in names if n in mesh.axis_names and n not in used)
        size = _axis_size(mesh, names)
        if not names or size == 1 or dim % size != 0:
            # partial fallback: try dropping trailing axes until divisible
            while names and (dim % _axis_size(mesh, names) != 0):
                names = names[:-1]
            if not names:
                out.append(None)
                continue
        used.update(names)
        out.append(names[0] if len(names) == 1 else names)
    return P(*out)


def sharding_for_tree(mesh: Mesh, params, rules=None):
    """Param tree -> NamedSharding tree (same structure as ``unbox``)."""

    def one(p: Param):
        shape = jax.eval_shape(lambda x: x, p.value).shape if not hasattr(p.value, "shape") else p.value.shape
        return NamedSharding(mesh, spec_for(mesh, p.axes, shape, rules))

    return jax.tree.map(one, params, is_leaf=lambda x: isinstance(x, Param))


# -----------------------------------------------------------------------------
# Mesh context: models call ``constrain`` freely; it is the identity when no
# mesh is active (single-device tests) and a sharding constraint otherwise.
# -----------------------------------------------------------------------------

_CTX = threading.local()


def set_mesh(mesh: Mesh | None):
    _CTX.mesh = mesh


def get_mesh() -> Mesh | None:
    return getattr(_CTX, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        set_mesh(prev)


def constrain(x, *axes, rules=None):
    """with_sharding_constraint under the active mesh; no-op without one."""
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(mesh, axes, x.shape, rules))
    )
