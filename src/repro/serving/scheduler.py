"""SLO-aware scheduling: per-slot adaptive draft depth for the serving fleet.

The engine's draft depth ``d`` (tree expansions per round) was a single
global knob, but acceptance rates vary wildly per request — the open
adaptation problem called out by the speculative-decoding surveys and solved
on-the-fly by SWIFT (arXiv:2410.06916).  A request whose measured acceptance
is ~1 token/round wastes most of a depth-4 tree (the unaccepted levels are
pure draft latency); a request accepting ~4 tokens/round is starved by a
depth-1 tree (extra verification rounds for the same stream).  This module
closes the loop:

``AdaptiveDepthController``
    One per ``EngineStepper``.  Each slot carries an EMA of its measured
    per-round acceptance (fed from the same observations as the
    ``serving_accept_depth`` histogram; a fresh slot is seeded from that
    histogram's running mean, so a warm replica starts new requests at the
    fleet's observed operating point).  The EMA maps to a depth *bucket* —
    ``SchedulerConfig.depth_buckets``, e.g. ``(1, 2, 3, 4)`` — and the
    round's effective depth is the max bucket over occupied slots (depth is
    a round-level property of the shared tree batch; extra depth never
    changes a neighbor's tokens, only spends draft time).  Bucketing is the
    recompile bound: depth enters ``EngineSession.step`` /
    ``draft_next_tree`` as a host-side Python loop count over the one jitted
    ``_expand`` program, so the jit cache is *independent* of how depths
    vary round to round (tests assert the compile count stays flat across
    every bucket).

Correctness contract: adaptation changes *when* tokens verify, never
*which* tokens a row emits — greedy verification pins each row's stream to
target-only greedy decoding at any depth, so any per-slot depth schedule is
byte-identical to solo ``generate()`` (tests/test_scheduler.py).

Deadline semantics (the other half of SLO-aware scheduling) live in
``repro.serving.queue`` (EDF pop with a starvation bound) and
``repro.serving.runtime`` (deadline-slack-aware routing); the SLO metrics
land in ``repro.serving.stats``.  See docs/scheduling.md.
"""

from __future__ import annotations

import bisect
import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Adaptive-depth policy knobs.

    ``depth_buckets``
        The admissible round depths, ascending.  Each bucket is one
        host-side loop count over the shared jitted expand program — the
        bucket count bounds scheduling-induced recompiles at zero new
        traces (the program is depth-independent), and bounds the distinct
        round shapes the fleet can emit.
    ``thresholds``
        Ascending acceptance-EMA cut points, one fewer than the buckets:
        bucket ``i`` is chosen while ``thresholds[i-1] <= ema <
        thresholds[i]``.  None derives ``(1.0, 2.0, ...)`` — draft roughly
        as deep as the tokens/round the slot actually sustains, the SWIFT
        heuristic (accepted tokens consume tree depth; drafting much past
        measured acceptance is latency with no expected yield).
    ``ema_alpha``
        Weight of the newest round in the per-slot acceptance EMA.
    ``seed_acceptance``
        Explicit EMA seed for fresh slots.  None: seed from the replica's
        ``serving_accept_depth`` histogram mean when it has observations,
        else fall back to the engine's configured global depth.
    """

    depth_buckets: tuple[int, ...] = (1, 2, 3, 4)
    thresholds: tuple[float, ...] | None = None
    ema_alpha: float = 0.25
    seed_acceptance: float | None = None

    def __post_init__(self):
        b = tuple(int(d) for d in self.depth_buckets)
        if not b or any(d < 1 for d in b) or any(y <= x for x, y in zip(b, b[1:])):
            raise ValueError(
                f"depth_buckets must be ascending positive ints, got {self.depth_buckets}")
        object.__setattr__(self, "depth_buckets", b)
        if self.thresholds is not None:
            t = tuple(float(x) for x in self.thresholds)
            if len(t) != len(b) - 1 or any(y <= x for x, y in zip(t, t[1:])):
                raise ValueError(
                    f"need {len(b) - 1} ascending thresholds for {len(b)} buckets, "
                    f"got {self.thresholds}")
            object.__setattr__(self, "thresholds", t)
        if not (0.0 < self.ema_alpha <= 1.0):
            raise ValueError(f"ema_alpha must be in (0, 1], got {self.ema_alpha}")

    @property
    def cut_points(self) -> tuple[float, ...]:
        """The resolved acceptance-EMA thresholds between buckets."""
        if self.thresholds is not None:
            return self.thresholds
        return tuple(float(i) for i in range(1, len(self.depth_buckets)))

    def bucket_for(self, ema: float) -> int:
        """Map an acceptance EMA to a draft depth (the bucket whose band
        contains it)."""
        return self.depth_buckets[bisect.bisect_right(self.cut_points, ema)]

    def clamp(self, depth: int) -> int:
        """The nearest admissible bucket to ``depth`` (ties go shallow —
        the cheaper round)."""
        return min(self.depth_buckets, key=lambda b: (abs(b - depth), b))


class AdaptiveDepthController:
    """Per-slot acceptance EMAs -> the round's effective draft depth.

    Owned by one ``EngineStepper``; everything here is host arithmetic on
    already-transferred per-round ints, so it adds nothing to the hot
    round's device or sync schedule.
    """

    def __init__(self, cfg: SchedulerConfig, n_slots: int, *,
                 default_depth: int, seed_hist=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.default_depth = cfg.clamp(int(default_depth))
        # the replica's serving_accept_depth Histogram (repro.obs.metrics):
        # its running mean seeds fresh slots at the observed operating point
        self._seed_hist = seed_hist
        self._ema: list[float | None] = [None] * n_slots

    # ---- per-slot lifecycle (driven by the stepper) ----------------------
    def seed_slot(self, slot: int) -> None:
        """A request was admitted into ``slot``: start its EMA from the best
        prior available (explicit seed > histogram mean > no prior, which
        falls back to the engine's default depth until measurements land)."""
        if self.cfg.seed_acceptance is not None:
            self._ema[slot] = float(self.cfg.seed_acceptance)
        elif self._seed_hist is not None and getattr(self._seed_hist, "count", 0):
            self._ema[slot] = float(self._seed_hist.mean)
        else:
            self._ema[slot] = None

    def clear_slot(self, slot: int) -> None:
        """The slot retired; its acceptance history must not leak into the
        next occupant (they are different requests)."""
        self._ema[slot] = None

    def observe(self, slot: int, n_accepted: int) -> None:
        """Fold one round's measured acceptance for ``slot`` into its EMA."""
        a = self._ema[slot]
        x = float(n_accepted)
        self._ema[slot] = x if a is None else (1.0 - self.cfg.ema_alpha) * a \
            + self.cfg.ema_alpha * x

    # ---- read side -------------------------------------------------------
    def slot_ema(self, slot: int) -> float | None:
        return self._ema[slot]

    def slot_depth(self, slot: int) -> int:
        """The depth bucket this slot's EMA currently selects."""
        a = self._ema[slot]
        return self.default_depth if a is None else self.cfg.bucket_for(a)

    def round_depth(self, occupied) -> int:
        """The round's effective draft depth: the max bucket over occupied
        slots.  Depth is a property of the whole batched tree round, and max
        never under-serves a slot — a low-acceptance neighbor riding a
        deeper tree spends draft time but emits identical tokens (the
        byte-identity contract), while a high-acceptance slot in a too-
        shallow tree pays real extra verification rounds."""
        depths = [self.slot_depth(i) for i, occ in enumerate(occupied) if occ]
        return max(depths) if depths else self.default_depth


def deadline_slack(active, now: float) -> float:
    """Tightest remaining deadline slack (seconds) across an iterable of
    occupied-slot records carrying ``req.deadline_s`` (None entries and
    deadline-free requests are skipped); +inf when nothing is deadlined.
    The router subtracts this pressure signal when breaking occupancy ties,
    steering new admissions away from replicas that must finish something
    soon."""
    slacks = [a.req.deadline_s - now for a in active
              if a is not None and a.req.deadline_s is not None]
    return min(slacks) if slacks else math.inf
