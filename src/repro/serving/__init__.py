"""repro.serving — continuous-batching serving runtime for the async
speculative engine.

The paper's headline number is an end-to-end *serving* result: the
disaggregated draft/target pipeline only pays off when it is kept full.  This
package turns the repo's one-shot ``SpecEngine.generate()`` into a request
runtime that multiplexes many independent requests through one engine with
per-slot lifecycles.

Modules
-------
``queue``
    ``Request`` and ``RequestQueue`` — FIFO with admission control: a hard
    queue cap (load shedding) and arrival-time gating so a seeded Poisson
    trace (``repro.data.make_request_trace``) replays like live traffic.
``runtime``
    ``ContinuousBatchingRuntime`` — the serving loop.  Admits requests into
    free engine slots (solo prefill installed into that slot's KV rows +
    per-slot tree re-seed), drives mixed-progress decode rounds through
    ``SpecEngine.step``, streams each request's verified tokens as they land,
    retires slots on EOS / max_new / cache budget, and immediately backfills
    from the queue.  ``WallClock`` / ``VirtualClock`` make trace replay real
    or deterministic.
``stats``
    ``ServerStats`` — per-request TTFT, decode tok/s, acceptance rate, slot
    and round lifetimes (overlapping round intervals are the evidence of
    continuous batching), plus per-round occupancy and queue-depth samples.

Correctness contract: greedy verification makes every row's emitted stream
equal target-only greedy decoding, independent of its neighbors — so each
request's output is byte-identical to a solo ``generate()`` run regardless of
when it was admitted or which slot it recycled (tests/test_serving.py).

Quick start::

    from repro.serving import ContinuousBatchingRuntime, Request

    rt = ContinuousBatchingRuntime(engine, tparams, dparams, n_slots=4)
    for i, prompt in enumerate(prompts):
        rt.submit(Request(rid=i, prompt=prompt, max_new=64))
    outputs = rt.run()          # {rid: [tokens]}
    print(rt.stats.report())    # TTFT / tok-s / occupancy / acceptance

See also ``examples/continuous_serving.py`` and
``python -m repro.launch.serve --continuous``.
"""

from repro.serving.queue import Request, RequestQueue
from repro.serving.runtime import ContinuousBatchingRuntime, VirtualClock, WallClock
from repro.serving.stats import RequestRecord, ServerStats, percentile

__all__ = [
    "ContinuousBatchingRuntime",
    "Request",
    "RequestQueue",
    "RequestRecord",
    "ServerStats",
    "VirtualClock",
    "WallClock",
    "percentile",
]
