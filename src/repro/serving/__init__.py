"""repro.serving — continuous-batching serving runtimes for the async
speculative engine, from one engine to a sharded fleet.

The paper's headline number is an end-to-end *serving* result: the
disaggregated draft/target pipeline only pays off when it is kept full —
and at scale, when many such pipelines are kept full at once.  This package
turns the repo's one-shot ``SpecEngine.generate()`` into request runtimes
that multiplex many independent requests through per-slot lifecycles, on
one engine or across N engine replicas on disjoint device groups.

Modules
-------
``queue``
    ``Request`` and ``RequestQueue`` — admission control plus a deadline-
    aware pop: a hard queue cap (load shedding), arrival-time gating so a
    seeded Poisson trace (``repro.data.make_request_trace``) replays like
    live traffic, and EDF selection among arrived requests — ``(priority,
    deadline, FIFO)`` with a ``starvation_s`` bound — that degenerates to
    exact FIFO when nothing carries a deadline.  Both admission gates (cap
    and prompt-length bound) adjudicate at ARRIVAL time; ``depth()`` is
    O(1) via an arrived/future split.
``scheduler``
    ``SchedulerConfig`` / ``AdaptiveDepthController`` — per-slot adaptive
    draft depth: each slot's measured-acceptance EMA maps to a depth bucket
    (one host loop count over the single jitted expand program — no new jit
    traces), and the round runs at the max bucket over occupied slots.
    Adaptation changes when tokens verify, never which tokens.
``runtime``
    ``EngineStepper`` — the per-engine admit/absorb/retire loop over one
    ``SpecEngine`` state: solo prefill installed into a free slot's KV rows
    + per-slot tree re-seed on admit, mixed-progress decode rounds through
    ``SpecEngine.step`` with streaming, slot release + backfill on retire.
    ``ContinuousBatchingRuntime`` — one stepper over one queue (the single-
    engine serving loop).  ``WallClock`` / ``VirtualClock`` make trace
    replay real or deterministic.
``router``
    ``ShardedServingRuntime`` — N steppers (one per SpecEngine replica,
    each on its own disjoint device-group pair from
    ``repro.launch.mesh.make_serving_mesh(..., replicas=N)``) fed from ONE
    global queue with depth/occupancy-aware routing: least-loaded replica
    wins, FIFO tie-break, per-replica admission so a long prefill on one
    replica never stalls decode rounds on another.
``stats``
    ``ServerStats`` — per-request TTFT, decode tok/s, acceptance rate, slot
    and round lifetimes (overlapping round intervals are the evidence of
    continuous batching), plus per-round occupancy and queue-depth samples.
    ``merge_summary`` / ``fleet_report`` fold N per-replica ServerStats
    into one aggregate (global TTFT/throughput, per-replica occupancy).

Correctness contract: greedy verification makes every row's emitted stream
equal target-only greedy decoding, independent of its neighbors — so each
request's output is byte-identical to a solo ``generate()`` run regardless
of when it was admitted, which slot it recycled, or which replica served it
(tests/test_serving.py, tests/test_router.py).

Quick start::

    from repro.serving import ContinuousBatchingRuntime, Request

    rt = ContinuousBatchingRuntime(engine, tparams, dparams, n_slots=4)
    for i, prompt in enumerate(prompts):
        rt.submit(Request(rid=i, prompt=prompt, max_new=64))
    outputs = rt.run()          # {rid: [tokens]}
    print(rt.stats.report())    # TTFT / tok-s / occupancy / acceptance

Sharded::

    from repro.serving import ShardedServingRuntime

    rt = ShardedServingRuntime([engine_a, engine_b], tparams, dparams, n_slots=4)
    rt.submit_trace(requests)
    outputs = rt.run()
    print(rt.report())          # per-replica occupancy + fleet aggregate

See also ``examples/continuous_serving.py`` and
``python -m repro.launch.serve --continuous [--replicas N]``.
"""

from repro.serving.queue import Request, RequestQueue
from repro.serving.router import ShardedServingRuntime
from repro.serving.runtime import (
    ContinuousBatchingRuntime,
    EngineStepper,
    VirtualClock,
    WallClock,
)
from repro.serving.scheduler import AdaptiveDepthController, SchedulerConfig
from repro.serving.stats import (
    RequestRecord,
    ServerStats,
    fleet_report,
    merge_summary,
    percentile,
)

__all__ = [
    "AdaptiveDepthController",
    "ContinuousBatchingRuntime",
    "EngineStepper",
    "Request",
    "SchedulerConfig",
    "RequestQueue",
    "RequestRecord",
    "ServerStats",
    "ShardedServingRuntime",
    "VirtualClock",
    "WallClock",
    "fleet_report",
    "merge_summary",
    "percentile",
]
