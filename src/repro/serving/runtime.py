"""ContinuousBatchingRuntime — multiplex many independent requests through
one SpecEngine with per-slot lifecycles.

The engine's jitted round (``SpecEngine.step``) always advances all B batch
rows; this runtime gives each row (a *slot*) its own request lifecycle:

  admit   — pop an arrived request from the queue into a free slot
            (solo prefill installed into the slot's cache rows, per-slot
            tree re-seed) — neighbors keep decoding untouched;
  decode  — mixed-progress rounds: every occupied slot emits its verified
            tokens each round, streamed to the caller as they land;
  retire  — on EOS / max_new / cache budget the slot is released (tree
            parked, KV rows zeroed) and immediately backfilled from the
            queue on the next loop turn.

Because greedy verification makes each row's emitted stream equal target-only
greedy decoding regardless of what the other rows are doing, a request's
output is byte-identical to a solo ``generate()`` run no matter when it was
admitted (tests/test_serving.py asserts this).

The clock is injectable: ``WallClock`` replays a trace against real time
(sleeping until the next arrival when idle); ``VirtualClock`` advances a
deterministic amount per engine round, so tests and benchmarks get
reproducible admission schedules independent of host speed.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

from repro.core.engine import absorb_emitted
from repro.serving.queue import Request, RequestQueue
from repro.serving.stats import ServerStats


class WallClock:
    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def reset(self) -> None:
        """Re-zero the serving timeline (run() calls this so construction-time
        jit compiles don't consume the trace's arrival schedule)."""
        self._t0 = time.perf_counter()

    def on_round(self) -> None:  # real time advances by itself
        pass

    def wait_until(self, t: float) -> None:
        d = t - self.now()
        if d > 0:
            time.sleep(d)


class VirtualClock:
    """Deterministic clock: ``round_dt`` virtual seconds per engine round."""

    def __init__(self, round_dt: float = 1.0):
        self._t = 0.0
        self.round_dt = round_dt

    def now(self) -> float:
        return self._t

    def reset(self) -> None:
        self._t = 0.0

    def on_round(self) -> None:
        self._t += self.round_dt

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, t)


@dataclasses.dataclass
class _Active:
    """Host-side bookkeeping for one occupied slot."""

    req: Request
    plen: int  # host mirror of the slot's device prefix length
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False


class ContinuousBatchingRuntime:
    """Drives one SpecEngine state of ``n_slots`` batch rows over a request
    queue.  ``stream(rid, new_tokens, done)`` is called once per round per
    occupied slot with that round's freshly verified tokens."""

    def __init__(self, engine, tparams, dparams, n_slots: int, *,
                 queue: RequestQueue | None = None,
                 clock=None,
                 stats: ServerStats | None = None,
                 stream: Callable[[int, list, bool], None] | None = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.engine, self.tparams, self.dparams = engine, tparams, dparams
        self.n_slots = n_slots
        self.queue = queue if queue is not None else RequestQueue()
        self.clock = clock if clock is not None else WallClock()
        self.stats = stats if stats is not None else ServerStats()
        self.stream = stream
        self.state = engine.init_state(n_slots)
        self.slots: list[_Active | None] = [None] * n_slots
        self.results: dict[int, list] = {}
        # trace entries whose arrival time is still in the future; they join
        # the queue when the clock reaches them, so the queue cap sheds on
        # ARRIVED backlog (live-traffic semantics), not on trace length
        self._pending: collections.deque[Request] = collections.deque()
        self._started = False  # pre-run submissions gate against t=0
        # verify rows reach plen-1+bs and the re-rooted tree needs headroom:
        # same safety margin generate() uses before its budget break
        self._plen_limit = min(engine.S_max_t, engine.S_max_d) - 2 * engine.cfg.bs

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request.  Rejected (False) when the prompt cannot fit the
        engine's cache budget, or — for already-arrived requests — when the
        queue is full.  A request with a future ``arrival_s`` is held outside
        the queue and faces the cap when its arrival time comes."""
        if req.prompt.size >= self._plen_limit:
            return self.queue.reject(req)
        # before run() the serving timeline hasn't started: arrivals compare
        # against t=0, not against however long engine construction took
        now = self.clock.now() if self._started else 0.0
        if req.arrival_s > now:
            if self._pending and req.arrival_s < self._pending[-1].arrival_s:
                raise ValueError("submissions must be ordered by arrival_s")
            self._pending.append(req)
            return True
        # already arrived (e.g. a live submit after a trace was served): it
        # arrives NOW on the serving timeline, keeping queue ordering intact
        # (a copy, so the caller's Request is not mutated)
        return self.queue.submit(dataclasses.replace(req, arrival_s=max(req.arrival_s, now)))

    def _feed_arrived(self) -> None:
        """Move trace entries whose arrival time has passed into the queue
        (where the cap may shed them)."""
        now = self.clock.now()
        while self._pending and self._pending[0].arrival_s <= now:
            self.queue.submit(self._pending.popleft())

    def submit_trace(self, requests) -> int:
        """Submit an iterable of Requests (arrival-ordered); returns #accepted."""
        return sum(1 for r in requests if self.submit(r))

    # ------------------------------------------------------------------
    @property
    def occupied(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def _admit_ready(self) -> None:
        """Backfill every free slot with an arrived request (FIFO)."""
        now = self.clock.now()
        for slot in range(self.n_slots):
            if self.slots[slot] is not None:
                continue
            req = self.queue.pop_ready(now)
            if req is None:
                return
            self.state = self.engine.admit_slot(
                self.tparams, self.dparams, self.state, slot, req.prompt)
            self.slots[slot] = _Active(req=req, plen=int(req.prompt.size))
            self.stats.on_admit(req.rid, slot, req.arrival_s, self.clock.now())

    def _retire(self, slot: int, act: _Active) -> None:
        self.results[act.req.rid] = act.out
        self.state = self.engine.release_slot(self.state, slot)
        self.slots[slot] = None
        self.stats.on_finish(act.req.rid, self.clock.now(), truncated=act.truncated)

    def _absorb(self, slot: int, act: _Active, res) -> None:
        """Fold one StepResult row into the slot's request: append verified
        tokens up to EOS/max_new, stream them, update the plen mirror."""
        # per-request eos/max_new fall back to the engine's, so the
        # byte-identical contract vs solo generate() holds for any SpecConfig
        eos = act.req.eos_id if act.req.eos_id is not None else self.engine.cfg.eos_id
        max_new = act.req.max_new if act.req.max_new is not None else self.engine.cfg.max_new
        new, act.done = absorb_emitted(
            act.out, res.emitted[slot], res.n_emitted[slot], max_new, eos)
        act.plen += int(res.n_emitted[slot])
        if act.plen >= self._plen_limit and not act.done:  # cache budget
            act.done = act.truncated = True
        self.stats.on_tokens(act.req.rid, len(new), int(res.n_accepted[slot]),
                             self.clock.now())
        if self.stream is not None and (new or act.done):
            self.stream(act.req.rid, new, act.done)

    def run(self) -> dict[int, list]:
        """Serve until the queue drains and every slot retires.  Returns
        {rid: emitted tokens}; telemetry accumulates in ``self.stats``."""
        if not self._started:
            self._started = True
            self.clock.reset()  # the trace timeline starts now
            self.stats.started_s = self.clock.now()  # later runs keep the
            # original start so summary() throughput spans all serving
        while self._pending or self.queue.pending or self.occupied:
            self._feed_arrived()
            self._admit_ready()
            if not self.occupied:
                nxt = self.queue.next_arrival()
                if nxt is None and self._pending:
                    nxt = self._pending[0].arrival_s
                if nxt is None:
                    break
                self.clock.wait_until(nxt)  # idle: jump to the next arrival
                continue
            self.state, res = self.engine.step(self.tparams, self.dparams, self.state)
            self.clock.on_round()
            self.stats.on_round(self.occupied, self.queue.depth(self.clock.now()))
            for slot, act in enumerate(self.slots):
                if act is None:
                    continue
                self._absorb(slot, act, res)
                if act.done:
                    self._retire(slot, act)
        self.stats.finished_s = self.clock.now()
        return self.results
