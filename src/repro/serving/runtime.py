"""Continuous-batching serving: per-slot request lifecycles over SpecEngine.

The engine's jitted round (``SpecEngine.step``) always advances all B batch
rows; ``EngineStepper`` gives each row (a *slot*) its own request lifecycle:

  admit   — install an arrived request into a free slot (solo prefill into
            the slot's cache rows, per-slot tree re-seed) — neighbors keep
            decoding untouched;
  decode  — mixed-progress rounds: every occupied slot emits its verified
            tokens each round, streamed to the caller as they land;
  retire  — on EOS / max_new / cache budget the slot is released (tree
            parked, KV rows zeroed) and immediately backfilled from the
            queue on the next loop turn.

``ContinuousBatchingRuntime`` drives ONE stepper over a ``RequestQueue``;
``ShardedServingRuntime`` (repro.serving.router) drives N of them over one
global queue with depth-aware routing.  Both share the same stepper, so the
slot lifecycle — and therefore the correctness contract — has exactly one
implementation: because greedy verification makes each row's emitted stream
equal target-only greedy decoding regardless of what the other rows are
doing, a request's output is byte-identical to a solo ``generate()`` run no
matter when it was admitted or which replica served it (tests/test_serving.py
and tests/test_router.py assert this).

The clock is injectable: ``WallClock`` replays a trace against real time
(sleeping until the next arrival when idle); ``VirtualClock`` advances a
deterministic amount per engine round, so tests and benchmarks get
reproducible admission schedules independent of host speed.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

from repro.core.engine import RoundInFlight, SpecStats, absorb_emitted
from repro.obs.clock import monotonic
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NOOP_SPAN, NULL_TRACER
from repro.serving.queue import Request, RequestQueue
from repro.serving.scheduler import (
    AdaptiveDepthController,
    SchedulerConfig,
    deadline_slack,
)
from repro.serving.stats import ServerStats

# accepted-depth histogram bucket for "replica admitted/finished" style
# counters is per-engine (0..bs); TTFT spans queueing so it gets the wide
# latency buckets below (virtual and wall clocks both land inside them)
TTFT_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0)


class WallClock:
    def __init__(self):
        self._t0 = monotonic()

    def now(self) -> float:
        return monotonic() - self._t0

    def reset(self) -> None:
        """Re-zero the serving timeline (run() calls this so construction-time
        jit compiles don't consume the trace's arrival schedule)."""
        self._t0 = monotonic()

    def on_round(self, depth: int | None = None) -> None:
        pass  # real time advances by itself

    def wait_until(self, t: float) -> None:
        d = t - self.now()
        if d > 0:
            time.sleep(d)


class VirtualClock:
    """Deterministic clock: ``round_dt`` virtual seconds per engine round,
    plus ``expand_dt`` per draft-tree expansion the round actually ran —
    the cost model that makes adaptive draft depth *measurable* on the
    virtual timeline (a depth-1 round is cheaper than a depth-4 round, as
    on hardware where each expansion is a serialized draft forward pass).
    ``expand_dt=0`` (the default) keeps the legacy fixed-cost rounds."""

    def __init__(self, round_dt: float = 1.0, expand_dt: float = 0.0):
        self._t = 0.0
        self.round_dt = round_dt
        self.expand_dt = expand_dt

    def now(self) -> float:
        return self._t

    def reset(self) -> None:
        self._t = 0.0

    def on_round(self, depth: int | None = None) -> None:
        self._t += self.round_dt + (self.expand_dt * depth if depth else 0.0)

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, t)


@dataclasses.dataclass
class _Active:
    """Host-side bookkeeping for one occupied slot."""

    req: Request
    plen: int  # host mirror of the slot's device prefix length
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False


class EngineStepper:
    """The per-engine admit/absorb/retire loop over one SpecEngine state.

    One stepper owns one ``EngineState`` of ``n_slots`` rows plus the
    host-side slot bookkeeping; the serving runtimes own the queue, the
    clock, and the decision of WHICH stepper a request lands on.  All
    device work (``admit`` prefills, ``step`` rounds) dispatches onto this
    engine's own mesh pair, so in a sharded fleet one replica's admission
    prefill is enqueued asynchronously on its device groups and never
    blocks another replica's decode round (the host only syncs inside
    ``SpecEngine.step``'s verified-token transfer).
    """

    def __init__(self, engine, tparams, dparams, n_slots: int, *,
                 stats: ServerStats | None = None,
                 stream: Callable[[int, list, bool], None] | None = None,
                 results: dict | None = None,
                 replica: int = 0,
                 tracer=None,
                 metrics: MetricsRegistry | None = None,
                 scheduler: SchedulerConfig | None = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.engine, self.tparams, self.dparams = engine, tparams, dparams
        self.n_slots = n_slots
        self.replica = replica
        self.stats = stats if stats is not None else ServerStats()
        self.stream = stream
        self.results = results if results is not None else {}
        self.slots: list[_Active | None] = [None] * n_slots
        # the engine's KV-budget bound (shared with generate(), so serving
        # truncates at exactly the same token as a solo run)
        self.plen_limit = engine.plen_budget
        # ---- observability (repro.obs): spans on this replica's track, and
        # cached metric handles so the hot path touches one object each
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.track = f"replica{replica}"
        self._round_span = NOOP_SPAN
        # the bound round API: params + EngineState + tracer, one per replica
        self.session = engine.session(
            tparams, dparams, n_slots=n_slots, tracer=self.tracer,
            track=self.track)
        self.spec_stats = SpecStats()  # engine-level round accounting
        rep = str(replica)
        m = self.metrics
        self._m_rounds = m.counter("serving_rounds_total", replica=rep)
        self._m_admitted = m.counter("serving_admitted_total", replica=rep)
        self._m_finished = m.counter("serving_finished_total", replica=rep)
        self._m_truncated = m.counter("serving_kv_truncations_total", replica=rep)
        self._m_tokens = m.counter("serving_tokens_total", replica=rep)
        # exact per-depth distribution: one bucket per possible accepted
        # count (0..bs) — ROADMAP #2's adaptive-depth signal
        self._m_accept = m.histogram(
            "serving_accept_depth", buckets=tuple(range(engine.cfg.bs + 1)),
            replica=rep)
        self._m_ttft = m.histogram("serving_ttft_seconds", buckets=TTFT_BUCKETS,
                                   replica=rep)
        self._m_occupancy = m.series("serving_occupancy", replica=rep)
        self._m_spec_commits = m.counter("serving_spec_commits_total", replica=rep)
        self._m_depth = m.series("serving_round_depth", replica=rep)
        # ---- adaptive draft depth (repro.serving.scheduler): per-slot
        # acceptance EMAs seeded from the accept-depth histogram above; None
        # keeps the engine's fixed global d (the pre-scheduler behavior)
        self.depth_ctl = None
        if scheduler is not None:
            self.depth_ctl = AdaptiveDepthController(
                scheduler, n_slots, default_depth=engine.cfg.d,
                seed_hist=self._m_accept)
        # the depth the most recent step() ran at (the round's cost driver,
        # read by the fleet loop's clock and the round-depth series)
        self.last_round_depth = engine.cfg.d

    # ------------------------------------------------------------------
    @property
    def state(self):
        """The session's EngineState (back-compat view; tests poke at it)."""
        return self.session.state

    @state.setter
    def state(self, s):
        self.session.state = s

    @property
    def occupied(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def has_free_slot(self) -> bool:
        return any(s is None for s in self.slots)

    @property
    def load(self) -> float:
        """Occupancy fraction in [0, 1] — the routing signal."""
        return self.occupied / self.n_slots

    def deadline_slack(self, now: float) -> float:
        """Tightest remaining deadline slack across this replica's occupied
        slots (+inf when none is deadlined) — the router's SLO-pressure
        tie-break (see ``ServingRuntimeBase._route``)."""
        return deadline_slack(self.slots, now)

    # ------------------------------------------------------------------
    def admit(self, req: Request, now: float) -> int:
        """Install ``req`` into the first free slot; returns the slot.  The
        caller supplies ONE timestamp used for both the arrival gate and the
        ``on_admit`` stamp, so ``queue_s``/TTFT cannot be skewed by clock
        reads straddling the prefill dispatch."""
        slot = self.slots.index(None)
        with self.tracer.span("admit_prefill", self.track,
                              args={"rid": req.rid, "slot": slot,
                                    "plen": int(req.prompt.size)}):
            self.session.admit_slot(slot, req.prompt)
        self.slots[slot] = _Active(req=req, plen=int(req.prompt.size))
        self.stats.on_admit(req.rid, slot, req.arrival_s, now, replica=self.replica,
                            deadline_s=req.deadline_s, priority=req.priority)
        if self.depth_ctl is not None:
            self.depth_ctl.seed_slot(slot)
        self._m_admitted.inc()
        return slot

    def step(self):
        """Dispatch one engine round for every slot.  Lockstep: runs the full
        round and returns its StepResult.  Async (``cfg.async_rounds``):
        dispatches verify + the speculative next-round draft and returns the
        ``RoundInFlight`` WITHOUT syncing — the host is free to step the
        other replicas (the two-stage pipeline: one verify and one draft
        outstanding per replica) until ``absorb_round`` reconciles it.

        With an adaptive-depth scheduler bound, the round's effective depth
        is the controller's decision for the CURRENT occupancy (max depth
        bucket over occupied slots' acceptance EMAs); otherwise the engine's
        fixed global ``d``.  Either way ``last_round_depth`` records it for
        the fleet clock's cost model and the round-depth series.

        Opens this replica's ``round`` span; ``absorb_round`` closes it (or
        ``abort_round`` on a failed fleet turn), so the span brackets
        dispatch through absorption — the engine's phase spans
        (verify/draft/sync/reroot) plus ``absorb`` are its children."""
        self._round_span = self.tracer.begin("round", self.track)
        try:
            depth = None
            if self.depth_ctl is not None:
                depth = self.depth_ctl.round_depth(
                    [s is not None for s in self.slots])
            self.last_round_depth = self.engine.cfg.d if depth is None else depth
            self._round_span.set("depth", self.last_round_depth)
            if self.engine.cfg.async_rounds:
                return self.session.begin_round(depth=depth)
            return self.session.step(stats=self.spec_stats, depth=depth)
        except BaseException:
            # a failed dispatch must not leak the open round span
            self._round_span.end()
            self._round_span = NOOP_SPAN
            raise

    def absorb_round(self, res, now: float) -> None:
        """Fold one round's outcome into every occupied slot, retiring the
        rows that finished (EOS / max_new / cache budget).  An in-flight
        async round is reconciled here — prediction mismatches on
        unoccupied rows are ignored (``live`` mask), since parked trees
        never reach verification and admission overwrites the row.

        The round span closes via try/finally: an absorb that raises (a
        failing stream callback, a poisoned record) must leave the tracer
        balanced, not with this replica's round span open forever."""
        try:
            if isinstance(res, RoundInFlight):
                pre = self.spec_stats.spec_commits
                res = self.session.reconcile(
                    res, stats=self.spec_stats,
                    live=[s is not None for s in self.slots])
                if self.spec_stats.spec_commits > pre:
                    self._m_spec_commits.inc()
            self._m_occupancy.append(now, self.occupied)  # pre-retire, as stats does
            self._m_depth.append(now, self.last_round_depth)
            with self.tracer.span("absorb", self.track):
                for slot, act in enumerate(self.slots):
                    if act is None:
                        continue
                    self._absorb(slot, act, res, now)
                    if act.done:
                        self._retire(slot, act, now)
            self._m_rounds.inc()
        finally:
            self._round_span.end()
            self._round_span = NOOP_SPAN

    def abort_round(self, res) -> None:
        """Abandon a dispatched round whose ``absorb_round`` will never run
        (another replica's absorb raised and the fleet loop is unwinding).
        An in-flight async round is reconciled and its result discarded —
        the session's buffers were donated into the round, so dropping the
        ``RoundInFlight`` on the floor would orphan the session — and the
        open round span is closed so the tracer stays balanced."""
        try:
            if isinstance(res, RoundInFlight):
                self.session.reconcile(
                    res, live=[s is not None for s in self.slots])
        finally:
            self._round_span.end()
            self._round_span = NOOP_SPAN

    def _absorb(self, slot: int, act: _Active, res, now: float) -> None:
        """Append one StepResult row's verified tokens up to EOS/max_new,
        stream them, update the plen mirror."""
        # per-request eos/max_new fall back to the engine's, so the
        # byte-identical contract vs solo generate() holds for any SpecConfig
        eos = act.req.eos_id if act.req.eos_id is not None else self.engine.cfg.eos_id
        max_new = act.req.max_new if act.req.max_new is not None else self.engine.cfg.max_new
        new, act.done = absorb_emitted(
            act.out, res.emitted[slot], res.n_emitted[slot], max_new, eos)
        act.plen += int(res.n_emitted[slot])
        if act.plen >= self.plen_limit and not act.done:  # cache budget
            act.done = act.truncated = True
        first = self.stats.records[act.req.rid].first_token_s is None
        self.stats.on_tokens(act.req.rid, len(new), int(res.n_accepted[slot]), now)
        self._m_accept.observe(int(res.n_accepted[slot]))
        if self.depth_ctl is not None:  # the same measurement feeds the EMA
            self.depth_ctl.observe(slot, int(res.n_accepted[slot]))
        if new:
            self._m_tokens.inc(len(new))
            if first:
                self._m_ttft.observe(now - act.req.arrival_s)
        if self.stream is not None and (new or act.done):
            self.stream(act.req.rid, new, act.done)

    def _retire(self, slot: int, act: _Active, now: float) -> None:
        self.results[act.req.rid] = act.out
        with self.tracer.span("retire", self.track, args={"rid": act.req.rid,
                                                          "slot": slot}):
            self.session.release_slot(slot)
        self.slots[slot] = None
        if self.depth_ctl is not None:  # acceptance history dies with the request
            self.depth_ctl.clear_slot(slot)
        self.stats.on_finish(act.req.rid, now, truncated=act.truncated)
        self._m_finished.inc()
        if act.truncated:
            self._m_truncated.inc()


class ServingRuntimeBase:
    """The serve loop over a fleet of steppers: trace submission, arrival
    feeding, routed admission, the round loop, and idle handling — shared by
    the single-engine runtime (a 1-stepper fleet) and the sharded runtime
    (N steppers), so both admission semantics and the round schedule have
    exactly one implementation.

    Subclasses call ``_init_admission`` then ``_init_fleet`` from their
    constructors.
    """

    def _init_admission(self, queue: RequestQueue | None, clock,
                        tracer=None, metrics: MetricsRegistry | None = None) -> None:
        self.queue = queue if queue is not None else RequestQueue()
        self.clock = clock if clock is not None else WallClock()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_queue_depth = self.metrics.series("serving_queue_depth")
        self.results: dict[int, list] = {}
        # trace entries whose arrival time is still in the future; they join
        # the queue when the clock reaches them, so BOTH admission gates (the
        # queue cap and the prompt-length bound) shed on ARRIVED traffic —
        # live semantics — not at trace-submission time
        self._pending: collections.deque[Request] = collections.deque()
        self._started = False  # pre-run submissions gate against t=0

    def submit(self, req: Request) -> bool:
        """Queue a request.  A request with a future ``arrival_s`` is held
        outside the queue and faces BOTH admission gates — the queue cap and
        the engine's prompt-length bound — when its arrival time comes, so
        ``RequestQueue.submitted``/``rejected`` count live traffic, not trace
        length.  An already-arrived request is adjudicated immediately:
        rejected (False) when its prompt cannot fit the cache budget or the
        queue is full."""
        # before run() the serving timeline hasn't started: arrivals compare
        # against t=0, not against however long engine construction took
        now = self.clock.now() if self._started else 0.0
        if req.arrival_s > now:
            if self._pending and req.arrival_s < self._pending[-1].arrival_s:
                raise ValueError("submissions must be ordered by arrival_s")
            self._pending.append(req)
            return True
        # a live submit after its arrival time arrives NOW on the serving
        # timeline, keeping queue ordering intact (a copy, so the caller's
        # Request is not mutated); trace entries fed by _feed_arrived keep
        # their true arrival_s — queueing delay belongs in their TTFT
        if req.arrival_s < now:
            req = dataclasses.replace(req, arrival_s=now)
        return self._arrive(req)

    def _arrive(self, req: Request) -> bool:
        """Run the arrival-time admission gates for one request."""
        if req.prompt.size >= self._plen_limit:
            return self.queue.reject(req)
        return self.queue.submit(req)

    def _feed_arrived(self) -> None:
        """Move trace entries whose arrival time has passed through the
        arrival gates (where the cap / prompt bound may shed them)."""
        now = self.clock.now()
        while self._pending and self._pending[0].arrival_s <= now:
            self._arrive(self._pending.popleft())

    def submit_trace(self, requests) -> int:
        """Submit an iterable of Requests (arrival-ordered); returns #accepted
        (future arrivals count as accepted here and are adjudicated on
        arrival)."""
        return sum(1 for r in requests if self.submit(r))

    def _next_arrival(self) -> float | None:
        nxt = self.queue.next_arrival()
        if nxt is None and self._pending:
            nxt = self._pending[0].arrival_s
        return nxt

    def _start_run(self) -> bool:
        """First run() call re-zeros the clock (construction-time jit
        compiles must not consume the trace's arrival schedule); later runs
        keep the original timeline.  Returns True on the first start."""
        if self._started:
            return False
        self._started = True
        self.clock.reset()
        return True

    # ---- the fleet loop ----------------------------------------------
    def _init_fleet(self, steppers: list[EngineStepper]) -> None:
        self.steppers = steppers
        # replicas could in principle differ; admission must fit the tightest
        self._plen_limit = min(s.plen_limit for s in steppers)
        self._seq = 0
        self._last_dispatch = [-1] * len(steppers)

    @property
    def occupied(self) -> int:
        return sum(s.occupied for s in self.steppers)

    def _route(self, now: float) -> int | None:
        """Pick the admission target: least-loaded stepper (occupancy
        fraction) among those with a free slot.  Equal load breaks on
        deadline slack — the replica whose in-flight work has the MOST
        remaining slack wins, so a new admission (whose rounds every
        co-resident request shares) is steered away from the replica that
        must finish something soonest.  Replicas with no deadlined work
        have infinite slack and tie, falling through to the FIFO tie-break
        — the stepper whose last admission is oldest — so deadline-free
        fleets keep the round-robin spread exactly.  None when the fleet is
        full.  (With one stepper this degenerates to "is a slot free".)"""
        best_key, best = None, None
        for i, st in enumerate(self.steppers):
            if not st.has_free_slot:
                continue
            key = (st.load, -st.deadline_slack(now), self._last_dispatch[i])
            if best_key is None or key < best_key:
                best_key, best = key, i
        return best

    def _admit_ready(self) -> None:
        """Drain arrived requests into free slots fleet-wide, one routing
        decision per request (the queue's deadline-aware pop picks WHICH
        request, ``_route`` picks WHERE); each admission reads the clock
        ONCE — the same timestamp keys the routing slack, gates the pop,
        and stamps ``on_admit``."""
        while True:
            now = self.clock.now()
            route_span = self.tracer.begin("route", "router")
            target = self._route(now)
            if target is None:
                route_span.end()
                return
            with self.tracer.span("queue_pop", "router"):
                req = self.queue.pop_ready(now)
            if req is None:
                route_span.end()
                return
            route_span.set("replica", target)
            route_span.set("rid", req.rid)
            route_span.end()
            self.steppers[target].admit(req, now)
            self._seq += 1
            self._last_dispatch[target] = self._seq

    def run(self) -> dict[int, list]:
        """Serve until the queue drains and every slot retires.  Returns the
        merged {rid: emitted tokens}; telemetry accumulates in each stepper's
        ServerStats."""
        if self._start_run():
            t0 = self.clock.now()
            for st in self.steppers:
                st.stats.started_s = t0  # later runs keep the original
                # start so summary() throughput spans all serving
        while self._pending or self.queue.pending or self.occupied:
            self._feed_arrived()
            self._admit_ready()
            busy = [st for st in self.steppers if st.occupied]
            if not busy:
                nxt = self._next_arrival()
                if nxt is None:
                    break
                with self.tracer.span("idle", "router"):
                    self.clock.wait_until(nxt)  # idle: jump to the next arrival
                continue
            # one global round: every busy stepper steps (concurrent across
            # disjoint device groups on real hardware), the clock ticks once,
            # then every stepper absorbs and retires.  If any dispatch or
            # absorb raises, every other dispatched round is aborted on the
            # way out — no open round span, no orphaned RoundInFlight.
            stepped: list = []
            try:
                for st in busy:
                    stepped.append((st, st.step()))
                # the global round costs what the deepest replica round cost
                # (replicas run concurrently on disjoint device groups)
                self.clock.on_round(max(st.last_round_depth for st in busy))
                now = self.clock.now()
                qdepth = self.queue.depth(now)
                self._m_queue_depth.append(now, qdepth)
                self.tracer.counter("queue_depth", qdepth)
                self.tracer.counter("occupied", self.occupied)
                while stepped:
                    st, res = stepped.pop(0)
                    st.stats.on_round(st.occupied, qdepth)
                    st.absorb_round(res, now)
            except BaseException:
                for st, res in stepped:
                    st.abort_round(res)
                raise
        t1 = self.clock.now()
        for st in self.steppers:
            st.stats.finished_s = t1
        return self.results


class ContinuousBatchingRuntime(ServingRuntimeBase):
    """Drives one SpecEngine state of ``n_slots`` batch rows over a request
    queue.  ``stream(rid, new_tokens, done)`` is called once per round per
    occupied slot with that round's freshly verified tokens."""

    def __init__(self, engine, tparams, dparams, n_slots: int, *,
                 queue: RequestQueue | None = None,
                 clock=None,
                 stats: ServerStats | None = None,
                 stream: Callable[[int, list, bool], None] | None = None,
                 tracer=None,
                 metrics: MetricsRegistry | None = None,
                 scheduler: SchedulerConfig | None = None):
        self._init_admission(queue, clock, tracer, metrics)
        self.stats = stats if stats is not None else ServerStats()
        self.stepper = EngineStepper(
            engine, tparams, dparams, n_slots,
            stats=self.stats, stream=stream, results=self.results,
            tracer=self.tracer, metrics=self.metrics, scheduler=scheduler)
        self._init_fleet([self.stepper])
        self.engine, self.n_slots = engine, n_slots

    # back-compat views (tests and callers poke at the engine state directly)
    @property
    def state(self):
        return self.stepper.state

    @property
    def slots(self):
        return self.stepper.slots
