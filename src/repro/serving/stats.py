"""ServerStats — telemetry for the continuous-batching runtime.

Per-request records (TTFT, decode tok/s, acceptance rate, slot + round
lifetime) plus per-round engine samples (slot occupancy, queue depth).  The
round-interval columns in ``report()`` are the direct evidence of continuous
batching: requests admitted mid-flight show overlapping [admit, finish)
round ranges.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    slot: int = -1
    replica: int = 0  # which engine replica served the request (sharded runtime)
    arrival_s: float = 0.0
    admitted_s: float = 0.0
    first_token_s: float | None = None
    finish_s: float | None = None
    admit_round: int = -1
    finish_round: int = -1
    n_tokens: int = 0
    n_rounds: int = 0
    n_accepted: int = 0
    truncated: bool = False  # cut off by the KV budget, not EOS/max_new
    deadline_s: float | None = None  # absolute finish deadline; None: best-effort
    priority: int = 0

    @property
    def ttft_s(self) -> float | None:
        """Time to first token, measured from arrival (includes queueing)."""
        return None if self.first_token_s is None else self.first_token_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        return self.admitted_s - self.arrival_s

    @property
    def tok_per_s(self) -> float | None:
        """Decode throughput from admission to finish (excludes queueing)."""
        if self.finish_s is None or self.finish_s <= self.admitted_s:
            return None
        return self.n_tokens / (self.finish_s - self.admitted_s)

    @property
    def acceptance(self) -> float:
        """Accepted draft tokens per verification round.  A record with no
        rounds has no measurable acceptance: nan, per the repo's nan-marking
        convention — a floored 0.0 here would silently read as 'this request
        accepted nothing'."""
        return self.n_accepted / self.n_rounds if self.n_rounds else float("nan")

    @property
    def compression_ratio(self) -> float:
        """Emitted tokens per target inference (the paper's metric); nan
        before any round has run."""
        return self.n_tokens / self.n_rounds if self.n_rounds else float("nan")

    @property
    def slack_s(self) -> float | None:
        """Deadline slack at finish: positive met the SLO by that margin,
        negative missed by it.  None while unfinished or best-effort."""
        if self.deadline_s is None or self.finish_s is None:
            return None
        return self.deadline_s - self.finish_s

    @property
    def met_deadline(self) -> bool | None:
        """Whether the request finished by its deadline (None: best-effort
        or still in flight)."""
        s = self.slack_s
        return None if s is None else s >= 0.0


def percentile(xs, p: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), p)) if len(xs) else float("nan")


def _mean_acceptance(recs) -> float:
    """Rounds-weighted mean acceptance: total accepted over total rounds.
    An unweighted mean of per-request ratios let a 1-round request count
    the same as a 100-round request (the same bias PR 3 fixed in fleet
    occupancy).  Weighting by rounds also naturally excludes zero-round
    records (weight 0) instead of propagating their nan acceptance.  0.0
    with no records at all (matching ``mean_occupancy``); nan when records
    exist but no round ever ran (no measurement, not zero acceptance)."""
    if not recs:
        return 0.0
    rounds = sum(r.n_rounds for r in recs)
    if not rounds:
        return float("nan")
    return sum(r.n_accepted for r in recs) / rounds


def _slo_fields(recs) -> dict:
    """SLO attainment + slack percentiles over finished records.  Only
    deadlined requests enter: attainment over best-effort traffic is not a
    meaningful SLO.  nan-marked when nothing carried a deadline."""
    slacks = [r.slack_s for r in recs if r.slack_s is not None]
    met = sum(1 for s in slacks if s >= 0.0)
    return {
        "n_deadlined": len(slacks),
        "slo_attainment": met / len(slacks) if slacks else float("nan"),
        "slack_p50_s": percentile(slacks, 50),
        "slack_p10_s": percentile(slacks, 10),  # near-worst-case margin
    }


def _fmt_or_dash(v: float | None, spec: str) -> str:
    """Render a telemetry cell: ``-`` for missing (None/nan) values."""
    if v is None or (isinstance(v, float) and np.isnan(v)):
        return "-"
    return format(v, spec)


class ServerStats:
    def __init__(self):
        self.records: dict[int, RequestRecord] = {}
        self.rounds = 0
        self.occupancy_samples: list[int] = []
        self.queue_depth_samples: list[int] = []
        self.started_s: float = 0.0
        self.finished_s: float = 0.0

    # ---- runtime hooks ---------------------------------------------------
    def on_admit(self, rid: int, slot: int, arrival_s: float, now: float,
                 replica: int = 0, deadline_s: float | None = None,
                 priority: int = 0) -> None:
        self.records[rid] = RequestRecord(
            rid=rid, slot=slot, replica=replica, arrival_s=arrival_s,
            admitted_s=now, admit_round=self.rounds,
            deadline_s=deadline_s, priority=priority,
        )

    def on_round(self, occupied: int, queue_depth: int) -> None:
        self.rounds += 1
        self.occupancy_samples.append(occupied)
        self.queue_depth_samples.append(queue_depth)

    def on_tokens(self, rid: int, n_new: int, n_accepted: int, now: float) -> None:
        r = self.records[rid]
        r.n_rounds += 1
        r.n_accepted += n_accepted
        if n_new > 0:
            if r.first_token_s is None:
                r.first_token_s = now
            r.n_tokens += n_new

    def on_finish(self, rid: int, now: float, truncated: bool = False) -> None:
        r = self.records[rid]
        r.finish_s = now
        r.finish_round = self.rounds
        r.truncated = truncated

    # ---- aggregates ------------------------------------------------------
    def finished_records(self) -> list[RequestRecord]:
        return [r for r in self.records.values() if r.finish_s is not None]

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy_samples)) if self.occupancy_samples else 0.0

    def summary(self) -> dict:
        recs = self.finished_records()
        ttfts = [r.ttft_s for r in recs if r.ttft_s is not None]
        total_tokens = sum(r.n_tokens for r in recs)
        # started_s/finished_s default to 0.0; a window that was never
        # stamped (or never advanced) has no meaningful width, so report nan
        # instead of a 1e-9-floor throughput in the trillions
        wall = self.finished_s - self.started_s
        return {
            "n_finished": len(recs),
            "total_tokens": total_tokens,
            "throughput_tok_s": total_tokens / wall if wall > 0 else float("nan"),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "mean_occupancy": self.mean_occupancy,
            "mean_acceptance": _mean_acceptance(recs),
            "rounds": self.rounds,
            **_slo_fields(recs),
        }

    def report(self) -> str:
        lines = ["rid slot  arrive  admit  rounds[admit,fin)   ttft_s  tok/s  accept  ntok  slack_s"]
        for r in sorted(self.records.values(), key=lambda r: r.rid):
            lines.append(
                f"{r.rid:3d} {r.slot:4d} {r.arrival_s:7.3f} {r.admitted_s:6.3f} "
                f"   [{r.admit_round:4d},{r.finish_round:4d})  "
                f"{_fmt_or_dash(r.ttft_s, '7.3f'):>7} {_fmt_or_dash(r.tok_per_s, '6.1f'):>6} "
                f"{_fmt_or_dash(r.acceptance, '7.2f'):>7} {r.n_tokens:5d} "
                f"{_fmt_or_dash(r.slack_s, '+8.3f'):>8}"
                + ("  TRUNCATED(kv-budget)" if r.truncated else "")
                + ("  LATE" if r.met_deadline is False else "")
            )
        s = self.summary()
        lines.append(
            f"aggregate: {s['n_finished']} finished, "
            f"{_fmt_or_dash(s['throughput_tok_s'], '.1f')} tok/s, "
            f"TTFT p50={_fmt_or_dash(s['ttft_p50_s'], '.3f')}s "
            f"p99={_fmt_or_dash(s['ttft_p99_s'], '.3f')}s, "
            f"occupancy {s['mean_occupancy']:.2f}, "
            f"acceptance {_fmt_or_dash(s['mean_acceptance'], '.2f')}"
            + (f", SLO {s['slo_attainment']:.0%} of {s['n_deadlined']} "
               f"(slack p50 {s['slack_p50_s']:+.3f}s p10 {s['slack_p10_s']:+.3f}s)"
               if s["n_deadlined"] else "")
        )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# multi-replica aggregation (sharded runtime: one ServerStats per replica)
# ---------------------------------------------------------------------------


def merge_summary(per_replica: list["ServerStats"], accept_hists=None) -> dict:
    """Fold N per-replica ServerStats into one fleet summary: global TTFT
    percentiles and throughput (tokens over the union of serving windows),
    rounds-weighted fleet acceptance, SLO attainment + slack percentiles
    over the fleet's deadlined requests, plus the per-replica occupancy/
    round breakdown that shows whether the router kept the fleet balanced.

    ``accept_hists`` (optional): the per-replica ``serving_accept_depth``
    Histogram objects.  Replicas may run different draft depths and so have
    different bucket edges — the merge unions the edges rather than summing
    counts positionally — and the result lands in ``accept_depth_mean`` /
    ``accept_depth_hist``."""
    recs = [r for st in per_replica for r in st.finished_records()]
    ttfts = [r.ttft_s for r in recs if r.ttft_s is not None]
    total_tokens = sum(r.n_tokens for r in recs)
    started = min((st.started_s for st in per_replica), default=0.0)
    finished = max((st.finished_s for st in per_replica), default=0.0)
    wall = finished - started
    # fleet occupancy weighted by each replica's round count: a replica that
    # sat idle (few rounds) must not drag the mean below what the busy
    # replicas actually sustained
    rounds = np.asarray([st.rounds for st in per_replica], np.float64)
    occs = np.asarray([st.mean_occupancy for st in per_replica], np.float64)
    extra: dict = {}
    if accept_hists:
        from repro.obs.metrics import merge_histograms

        merged = merge_histograms(accept_hists)
        extra["accept_depth_mean"] = merged.mean
        extra["accept_depth_hist"] = {
            "buckets": list(merged.buckets), "counts": list(merged.counts),
            "sum": merged.sum, "count": merged.count,
        }
    return {
        **extra,
        "n_replicas": len(per_replica),
        "n_finished": len(recs),
        "total_tokens": total_tokens,
        "throughput_tok_s": total_tokens / wall if wall > 0 else float("nan"),
        "ttft_p50_s": percentile(ttfts, 50),
        "ttft_p99_s": percentile(ttfts, 99),
        "mean_occupancy": (
            float((occs * rounds).sum() / rounds.sum()) if rounds.sum() else 0.0
        ),
        "per_replica_occupancy": [st.mean_occupancy for st in per_replica],
        "per_replica_finished": [len(st.finished_records()) for st in per_replica],
        "per_replica_rounds": [st.rounds for st in per_replica],
        "mean_acceptance": _mean_acceptance(recs),
        **_slo_fields(recs),
    }


def fleet_report(per_replica: list["ServerStats"]) -> str:
    """Human-readable fleet report: every request row (tagged with the
    replica that served it) in rid order, then per-replica occupancy, then
    the merged aggregate line."""
    lines = ["rid rep slot  arrive  admit  rounds[admit,fin)   ttft_s  tok/s  accept  ntok  slack_s"]
    allrecs = [r for st in per_replica for r in st.records.values()]
    for r in sorted(allrecs, key=lambda r: r.rid):
        lines.append(
            f"{r.rid:3d} {r.replica:3d} {r.slot:4d} {r.arrival_s:7.3f} {r.admitted_s:6.3f} "
            f"   [{r.admit_round:4d},{r.finish_round:4d})  "
            f"{_fmt_or_dash(r.ttft_s, '7.3f'):>7} {_fmt_or_dash(r.tok_per_s, '6.1f'):>6} "
            f"{_fmt_or_dash(r.acceptance, '7.2f'):>7} {r.n_tokens:5d} "
            f"{_fmt_or_dash(r.slack_s, '+8.3f'):>8}"
            + ("  TRUNCATED(kv-budget)" if r.truncated else "")
            + ("  LATE" if r.met_deadline is False else "")
        )
    s = merge_summary(per_replica)
    for i, st in enumerate(per_replica):
        lines.append(
            f"replica {i}: {len(st.finished_records())} finished over {st.rounds} rounds, "
            f"occupancy {st.mean_occupancy:.2f}"
        )
    lines.append(
        f"fleet: {s['n_finished']} finished, "
        f"{_fmt_or_dash(s['throughput_tok_s'], '.1f')} tok/s, "
        f"TTFT p50={_fmt_or_dash(s['ttft_p50_s'], '.3f')}s "
        f"p99={_fmt_or_dash(s['ttft_p99_s'], '.3f')}s, "
        f"acceptance {_fmt_or_dash(s['mean_acceptance'], '.2f')}"
        + (f", SLO {s['slo_attainment']:.0%} of {s['n_deadlined']} "
           f"(slack p50 {s['slack_p50_s']:+.3f}s p10 {s['slack_p10_s']:+.3f}s)"
           if s["n_deadlined"] else "")
    )
    return "\n".join(lines)
