"""Request queue with admission control for the continuous-batching runtime.

Arrival-ordered, with two admission gates:
  * a hard queue cap (``cap``): submissions beyond it are rejected at the
    door (counted in ``rejected``) instead of growing an unbounded backlog —
    the load-shedding half of admission control;
  * arrival-time gating: a request only becomes poppable once the serving
    clock has reached its ``arrival_s`` (replaying a recorded/Poisson trace
    behaves like live traffic).

The pop is deadline-aware (docs/scheduling.md): among ARRIVED requests,
``pop_ready`` picks by ``(priority, deadline, insertion order)`` — earliest
deadline first within a priority class, deadline-free requests last in
theirs, FIFO tie-break — so a tight-SLO arrival overtakes a best-effort
backlog.  A pure EDF pop can starve deadline-free work behind a steady
deadlined stream, so ``starvation_s`` bounds it: once the oldest arrived
request has waited that long, it pops next regardless of everyone else's
deadlines.  With no deadlines and no priorities the pop degenerates to
exact FIFO (the pre-scheduling behavior).

Internally the queue is an arrived list plus a future deque: ``_ready``
(requests whose arrival time is at or before the highest ``now`` seen so
far, in insertion order) and ``_future`` (not yet arrived).  Because
submissions are arrival-ordered, every ``_future`` entry arrives after
every ``_ready`` entry, so ``depth()`` is just ``len(_ready)`` — O(1) for
the monotonic clocks the runtimes use (each request crosses the boundary
exactly once) — and the EDF scan touches only the arrived backlog.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus per-request decode limits and SLO.

    ``deadline_s`` is an absolute point on the serving timeline (same clock
    as ``arrival_s``) by which the request should FINISH; None means
    best-effort.  ``priority`` orders pops before deadlines do — lower is
    more urgent (0 is the default class) — so an operator can pin
    interactive traffic ahead of batch traffic outright."""

    rid: int
    prompt: np.ndarray  # i32[P]
    arrival_s: float = 0.0
    max_new: int | None = None  # None: inherit the engine's max_new
    eos_id: int | None = None  # None: inherit the engine's eos_id; -1: never stop
    deadline_s: float | None = None  # absolute finish deadline; None: best-effort
    priority: int = 0  # lower pops first; ties fall through to EDF

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new is not None and self.max_new <= 0:
            raise ValueError(f"request {self.rid}: max_new must be positive")
        if self.deadline_s is not None and self.deadline_s < self.arrival_s:
            raise ValueError(
                f"request {self.rid}: deadline_s {self.deadline_s} precedes "
                f"arrival_s {self.arrival_s}")

    @property
    def edf_deadline(self) -> float:
        """The EDF sort key: best-effort requests order after any deadline."""
        return self.deadline_s if self.deadline_s is not None else float("inf")


class RequestQueue:
    def __init__(self, cap: int = 64, starvation_s: float | None = None):
        if starvation_s is not None and starvation_s <= 0:
            raise ValueError(f"starvation_s must be positive, got {starvation_s}")
        self.cap = cap
        # EDF starvation bound: once the oldest arrived request has waited
        # this long, it wins the pop regardless of deadlines (None: pure EDF)
        self.starvation_s = starvation_s
        self._ready: list[Request] = []  # arrived, in insertion (FIFO) order
        self._future: collections.deque[Request] = collections.deque()
        self.submitted = 0
        self.rejected = 0
        self._last_arrival = float("-inf")
        self._now_w = float("-inf")  # arrival watermark: max ``now`` seen

    def _advance(self, now: float) -> None:
        """Migrate newly arrived requests across the ready/future boundary
        (amortized O(1): each request crosses once under a monotonic clock)."""
        if now > self._now_w:
            self._now_w = now
        while self._future and self._future[0].arrival_s <= now:
            self._ready.append(self._future.popleft())

    def reject(self, req: Request) -> bool:
        """Count a request rejected by an external admission gate (e.g. the
        runtime's prompt-length check), keeping all accounting in one place."""
        self.submitted += 1
        self.rejected += 1
        return False

    def submit(self, req: Request) -> bool:
        """Admission control: returns False (and counts the shed) on a full
        queue.  FUTURE submissions must come in arrival order (trace replay);
        an out-of-order future submission raises without touching the
        counters, so ``submitted == queued + rejected`` always holds.  An
        already-arrived submission (``arrival_s`` at or behind the watermark)
        is always orderable — it queues behind everything already here, in
        submission order — so live submits racing a trace feed cannot poison
        the queue (the ready/future split stays sorted either way)."""
        if req.arrival_s > self._now_w and req.arrival_s < self._last_arrival:
            raise ValueError("future submissions must be ordered by arrival_s")
        self.submitted += 1
        if len(self._ready) + len(self._future) >= self.cap:
            self.rejected += 1
            return False
        self._last_arrival = max(self._last_arrival, req.arrival_s)
        if req.arrival_s <= self._now_w:
            self._ready.append(req)
        else:
            self._future.append(req)
        return True

    def pop_ready(self, now: float) -> Request | None:
        """Deadline-aware priority pop over the ARRIVED backlog, or None.

        Selection key: ``(priority, deadline, insertion order)`` — EDF
        within a priority class, best-effort (deadline-free) requests last
        in theirs, FIFO tie-break — which is exact FIFO when nothing
        carries a deadline or priority.  Starvation bound: with
        ``starvation_s`` set, an oldest-arrived request that has waited at
        least that long pops first unconditionally, so a steady deadlined
        stream cannot park best-effort work forever."""
        self._advance(now)
        # the watermark may sit ahead of a non-monotonic probe: re-check each
        # entry's arrival against THIS ``now`` so gating stays exact
        arrived = [i for i, r in enumerate(self._ready) if r.arrival_s <= now]
        if not arrived:
            return None
        oldest = arrived[0]  # insertion order == arrival order for traces
        if (self.starvation_s is not None
                and now - self._ready[oldest].arrival_s >= self.starvation_s):
            return self._ready.pop(oldest)
        best = min(arrived,
                   key=lambda i: (self._ready[i].priority,
                                  self._ready[i].edf_deadline, i))
        return self._ready.pop(best)

    def next_arrival(self) -> float | None:
        """Arrival time of the head request (None when empty)."""
        if self._ready:
            return self._ready[0].arrival_s
        return self._future[0].arrival_s if self._future else None

    def depth(self, now: float) -> int:
        """Requests that have arrived and are waiting for a slot.  O(1) for
        monotonic ``now``; a probe behind the watermark rescans exactly."""
        if now < self._now_w:
            return sum(1 for r in self._ready if r.arrival_s <= now)
        self._advance(now)
        return len(self._ready)

    @property
    def pending(self) -> int:
        """All waiting requests, including not-yet-arrived trace entries."""
        return len(self._ready) + len(self._future)

    def __len__(self) -> int:
        return len(self._ready) + len(self._future)
