"""Request queue with admission control for the continuous-batching runtime.

FIFO in arrival order, with two admission gates:
  * a hard queue cap (``cap``): submissions beyond it are rejected at the
    door (counted in ``rejected``) instead of growing an unbounded backlog —
    the load-shedding half of admission control;
  * arrival-time gating: a request only becomes poppable once the serving
    clock has reached its ``arrival_s`` (replaying a recorded/Poisson trace
    behaves like live traffic).

Internally the queue is two deques: ``_ready`` (requests whose arrival time
is at or before the highest ``now`` seen so far) and ``_future`` (not yet
arrived).  Because submissions are arrival-ordered, every ``_future`` entry
arrives after every ``_ready`` entry, so popping ``_ready``'s head is always
globally FIFO and ``depth()`` is just ``len(_ready)`` — O(1) for the
monotonic clocks the runtimes use (each request crosses the boundary exactly
once), instead of rescanning the whole backlog every round.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus per-request decode limits."""

    rid: int
    prompt: np.ndarray  # i32[P]
    arrival_s: float = 0.0
    max_new: int | None = None  # None: inherit the engine's max_new
    eos_id: int | None = None  # None: inherit the engine's eos_id; -1: never stop

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new is not None and self.max_new <= 0:
            raise ValueError(f"request {self.rid}: max_new must be positive")


class RequestQueue:
    def __init__(self, cap: int = 64):
        self.cap = cap
        self._ready: collections.deque[Request] = collections.deque()
        self._future: collections.deque[Request] = collections.deque()
        self.submitted = 0
        self.rejected = 0
        self._last_arrival = float("-inf")
        self._now_w = float("-inf")  # arrival watermark: max ``now`` seen

    def _advance(self, now: float) -> None:
        """Migrate newly arrived requests across the ready/future boundary
        (amortized O(1): each request crosses once under a monotonic clock)."""
        if now > self._now_w:
            self._now_w = now
        while self._future and self._future[0].arrival_s <= now:
            self._ready.append(self._future.popleft())

    def reject(self, req: Request) -> bool:
        """Count a request rejected by an external admission gate (e.g. the
        runtime's prompt-length check), keeping all accounting in one place."""
        self.submitted += 1
        self.rejected += 1
        return False

    def submit(self, req: Request) -> bool:
        """Admission control: returns False (and counts the shed) on a full
        queue.  FUTURE submissions must come in arrival order (trace replay);
        an out-of-order future submission raises without touching the
        counters, so ``submitted == queued + rejected`` always holds.  An
        already-arrived submission (``arrival_s`` at or behind the watermark)
        is always orderable — it queues behind everything already here, in
        submission order — so live submits racing a trace feed cannot poison
        the queue (the ready/future split stays sorted either way)."""
        if req.arrival_s > self._now_w and req.arrival_s < self._last_arrival:
            raise ValueError("future submissions must be ordered by arrival_s")
        self.submitted += 1
        if len(self._ready) + len(self._future) >= self.cap:
            self.rejected += 1
            return False
        self._last_arrival = max(self._last_arrival, req.arrival_s)
        if req.arrival_s <= self._now_w:
            self._ready.append(req)
        else:
            self._future.append(req)
        return True

    def pop_ready(self, now: float) -> Request | None:
        """Next request whose arrival time has passed, or None."""
        self._advance(now)
        # the watermark may sit ahead of a non-monotonic probe: re-check the
        # head's arrival against THIS ``now`` so gating stays exact
        if self._ready and self._ready[0].arrival_s <= now:
            return self._ready.popleft()
        return None

    def next_arrival(self) -> float | None:
        """Arrival time of the head request (None when empty)."""
        if self._ready:
            return self._ready[0].arrival_s
        return self._future[0].arrival_s if self._future else None

    def depth(self, now: float) -> int:
        """Requests that have arrived and are waiting for a slot.  O(1) for
        monotonic ``now``; a probe behind the watermark rescans exactly."""
        if now < self._now_w:
            return sum(1 for r in self._ready if r.arrival_s <= now)
        self._advance(now)
        return len(self._ready)

    @property
    def pending(self) -> int:
        """All waiting requests, including not-yet-arrived trace entries."""
        return len(self._ready) + len(self._future)

    def __len__(self) -> int:
        return len(self._ready) + len(self._future)
