"""Request queue with admission control for the continuous-batching runtime.

FIFO in arrival order, with two admission gates:
  * a hard queue cap (``cap``): submissions beyond it are rejected at the
    door (counted in ``rejected``) instead of growing an unbounded backlog —
    the load-shedding half of admission control;
  * arrival-time gating: a request only becomes poppable once the serving
    clock has reached its ``arrival_s`` (replaying a recorded/Poisson trace
    behaves like live traffic).
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus per-request decode limits."""

    rid: int
    prompt: np.ndarray  # i32[P]
    arrival_s: float = 0.0
    max_new: int | None = None  # None: inherit the engine's max_new
    eos_id: int | None = None  # None: inherit the engine's eos_id; -1: never stop

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new is not None and self.max_new <= 0:
            raise ValueError(f"request {self.rid}: max_new must be positive")


class RequestQueue:
    def __init__(self, cap: int = 64):
        self.cap = cap
        self._q: collections.deque[Request] = collections.deque()
        self.submitted = 0
        self.rejected = 0
        self._last_arrival = float("-inf")

    def reject(self, req: Request) -> bool:
        """Count a request rejected by an external admission gate (e.g. the
        runtime's prompt-length check), keeping all accounting in one place."""
        self.submitted += 1
        self.rejected += 1
        return False

    def submit(self, req: Request) -> bool:
        """Admission control: returns False (and counts the shed) on a full
        queue.  Submissions must come in arrival order (trace replay); an
        out-of-order submission raises without touching the counters, so
        ``submitted == queued + rejected`` always holds."""
        if req.arrival_s < self._last_arrival:
            raise ValueError("submissions must be ordered by arrival_s")
        self.submitted += 1
        if len(self._q) >= self.cap:
            self.rejected += 1
            return False
        self._last_arrival = req.arrival_s
        self._q.append(req)
        return True

    def pop_ready(self, now: float) -> Request | None:
        """Next request whose arrival time has passed, or None."""
        if self._q and self._q[0].arrival_s <= now:
            return self._q.popleft()
        return None

    def next_arrival(self) -> float | None:
        """Arrival time of the head request (None when empty)."""
        return self._q[0].arrival_s if self._q else None

    def depth(self, now: float) -> int:
        """Requests that have arrived and are waiting for a slot."""
        return sum(1 for r in self._q if r.arrival_s <= now)

    @property
    def pending(self) -> int:
        """All waiting requests, including not-yet-arrived trace entries."""
        return len(self._q)

    def __len__(self) -> int:
        return len(self._q)
