"""ShardedServingRuntime — one global request queue dispatched over N
SpecEngine replicas with depth-aware routing.

SwiftSpec's headline number comes from scaling the disaggregated pipeline
across device groups; this is the serving-side half of that scaling: each
replica is a full (draft group, target group) pair carved out of the slice
by ``repro.launch.mesh.make_serving_mesh(..., replicas=N)``, driven by its
own ``EngineStepper`` — the same per-slot admit/absorb/retire lifecycle and
the same fleet loop (``ServingRuntimeBase``) the single-engine runtime
uses, so the byte-identical contract holds per request regardless of which
replica served it, and the single-engine runtime is literally the N=1 case.

Routing policy (``ServingRuntimeBase._route``): a popped request lands on
the replica with the lowest occupancy fraction among those with a free
slot; ties break FIFO — the replica that has gone longest since its last
admission wins — so equal load spreads round-robin instead of piling onto
replica 0.

Per-replica admission: ``EngineStepper.admit`` dispatches the solo prefill
onto the OWNING replica's device groups only.  JAX's asynchronous dispatch
means the host enqueues replica A's (possibly long) prefill and moves
straight on to replica B's decode round — the only host sync is each
replica's own verified-token transfer inside ``SpecEngine.step`` — so a
long prompt admitted on A never stalls decode progress on B.

One global round of the fleet loop = every busy replica steps once (those
rounds run concurrently across disjoint device groups in a real
deployment), then the clock advances once, then every replica
absorbs/retires/backfills.  Telemetry is one ``ServerStats`` per replica,
merged by ``repro.serving.stats.merge_summary`` / ``fleet_report`` into
global TTFT and throughput plus the per-replica occupancy breakdown.
"""

from __future__ import annotations

from typing import Callable

from repro.serving.queue import RequestQueue
from repro.serving.runtime import EngineStepper, ServingRuntimeBase
from repro.serving.stats import ServerStats, fleet_report, merge_summary


class ShardedServingRuntime(ServingRuntimeBase):
    """N-replica continuous batching over one global ``RequestQueue``.

    ``engines`` is a list of SpecEngine replicas (each typically on its own
    disjoint mesh pair; passing the same engine object N times is valid —
    states are separate — and is what the CPU fallback does to share one jit
    cache).  ``tparams``/``dparams`` are either a single pytree shared by
    every replica or a list with one entry per replica (params resident on
    that replica's device groups).
    """

    def __init__(self, engines, tparams, dparams, n_slots: int, *,
                 queue: RequestQueue | None = None,
                 clock=None,
                 stream: Callable[[int, list, bool], None] | None = None,
                 tracer=None,
                 metrics=None,
                 scheduler=None):
        if not engines:
            raise ValueError("need at least one engine replica")
        self._init_admission(queue, clock, tracer, metrics)
        tps = tparams if isinstance(tparams, list) else [tparams] * len(engines)
        dps = dparams if isinstance(dparams, list) else [dparams] * len(engines)
        if not (len(tps) == len(dps) == len(engines)):
            raise ValueError("per-replica params must match the engine count")
        self._init_fleet([
            EngineStepper(eng, tp, dp, n_slots,
                          stats=ServerStats(), stream=stream,
                          results=self.results, replica=i,
                          tracer=self.tracer, metrics=self.metrics,
                          scheduler=scheduler)
            for i, (eng, tp, dp) in enumerate(zip(engines, tps, dps))
        ])

    # ------------------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.steppers)

    @property
    def stats(self) -> list[ServerStats]:
        """Per-replica telemetry (merge with ``summary()``/``report()``)."""
        return [s.stats for s in self.steppers]

    def summary(self) -> dict:
        # per-replica accept-depth histograms may have different bucket
        # edges (replicas can run different draft depths) — merge_summary
        # unions the edges instead of summing counts positionally
        hists = [h for _, h in self.metrics.histogram_family("serving_accept_depth")]
        return merge_summary(self.stats, accept_hists=hists or None)

    def report(self) -> str:
        return fleet_report(self.stats)

    def replica_of(self, rid: int) -> int | None:
        """Which replica served (or is serving) a request, None if unknown."""
        for i, st in enumerate(self.steppers):
            if rid in st.stats.records:
                return i
        return None
