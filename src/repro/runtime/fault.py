"""Fault tolerance: step retry with backoff, failure domains, straggler policy.

At 1000+ nodes, the relevant failures are (a) transient device/runtime errors
(retry the step — state is functional, so a retry is safe by construction),
(b) lost nodes (restore from the last checkpoint onto the surviving mesh —
ckpt/manager.py + runtime/elastic.py), and (c) stragglers.

Straggler mitigation for the serving engine is *draft-bypass* (DESIGN.md §5):
the asynchronous design means the target never waits on a slow draft group —
if the draft misses its deadline, verification proceeds on the best
already-available subtree and the engine degenerates gracefully toward
autoregressive decoding instead of stalling.  For training, the mitigation is
deterministic-data restart: any rank can be reconstructed from (seed, step).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, TypeVar

T = TypeVar("T")
log = logging.getLogger("repro.fault")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    max_retries: int = 3
    backoff_s: float = 0.5
    backoff_mult: float = 2.0
    # exceptions considered transient (retryable); XlaRuntimeError subclasses
    # RuntimeError, so device-side faults are covered.
    transient: tuple = (RuntimeError, OSError)


def retry_step(fn: Callable[[], T], cfg: FaultConfig = FaultConfig(),
               on_retry: Callable[[int, BaseException], None] | None = None) -> T:
    """Run ``fn`` with bounded retry + exponential backoff.

    Functional JAX steps are idempotent (no in-place state), so re-execution
    after a transient XLA/runtime error is safe.  Non-transient exceptions
    propagate immediately.
    """
    delay = cfg.backoff_s
    for attempt in range(cfg.max_retries + 1):
        try:
            return fn()
        except cfg.transient as e:  # noqa: PERF203
            if attempt == cfg.max_retries:
                raise
            log.warning("transient failure (attempt %d/%d): %s", attempt + 1, cfg.max_retries, e)
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(delay)
            delay *= cfg.backoff_mult
    raise AssertionError("unreachable")


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based draft-bypass decision for the serving engine.

    ``deadline_ratio``: the draft group must deliver within ratio × its
    profiled time; beyond that the engine verifies the best available subtree
    (SpecConfig.draft_bypass path).
    """

    t_draft_profiled_s: float
    deadline_ratio: float = 3.0
    window: int = 16  # sliding window of recent draft times

    def __post_init__(self):
        self._recent: list[float] = []

    def observe(self, t_draft_s: float) -> None:
        self._recent.append(t_draft_s)
        if len(self._recent) > self.window:
            self._recent.pop(0)

    @property
    def deadline_s(self) -> float:
        return self.t_draft_profiled_s * self.deadline_ratio

    def should_bypass(self) -> bool:
        """True when the recent draft latency trend blows the deadline."""
        if not self._recent:
            return False
        return self._recent[-1] > self.deadline_s
