from repro.runtime.fault import FaultConfig, retry_step, StragglerPolicy
from repro.runtime.elastic import reshard_engine, replan_split

__all__ = ["FaultConfig", "retry_step", "StragglerPolicy", "reshard_engine", "replan_split"]
