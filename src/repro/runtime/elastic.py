"""Elastic re-sharding: move the engine / train state onto a new device split.

Two elasticity events matter for SwiftSpec-style serving:
  * draft/target re-allocation — the profiling pass (core/scheduler.py) or a
    straggling draft group calls for a different x:(k-x) split; params must
    re-shard onto the new submeshes without dropping the conversation state;
  * shrink/grow — a pod or host is lost/added; train state restores from the
    checkpoint onto the surviving mesh (shardings are recomputed from the
    same logical-axis rules, so any mesh shape that divides the dims works).

Both reduce to "device_put the same logical tree under new NamedShardings",
which is exactly what these helpers do.
"""

from __future__ import annotations

import jax

from repro.sharding import sharding_for_tree, unbox


def submeshes(devices, n_target: int, axis_name: str = "model"):
    """Split a flat device list into (target_mesh, draft_mesh) 1-D TP meshes."""
    from jax.sharding import Mesh
    import numpy as np

    devs = list(devices)
    assert 1 <= n_target < len(devs) or len(devs) == 1, (n_target, len(devs))
    if len(devs) == 1:  # CPU container: both groups share the device
        m = Mesh(np.array(devs), (axis_name,))
        return m, m
    tgt = Mesh(np.array(devs[:n_target]), (axis_name,))
    drf = Mesh(np.array(devs[n_target:]), (axis_name,))
    return tgt, drf


def reshard_params(boxed_params, new_mesh, rules=None):
    """Re-place a Param tree's values under ``new_mesh``'s shardings."""
    sh = sharding_for_tree(new_mesh, boxed_params, rules)
    vals = unbox(boxed_params)
    return jax.tree.map(jax.device_put, vals, sh)


def reshard_engine(engine, tparams_boxed, dparams_boxed, devices, n_target: int):
    """Re-split devices as n_target:(rest) and re-shard both models.

    Returns (engine', tparams_vals, dparams_vals) — caches are rebuilt by the
    next generate() call; the draft tree is host-replicated state and moves
    for free.
    """
    tgt, drf = submeshes(devices, n_target)
    engine.mesh_target, engine.mesh_draft = tgt, drf
    tvals = reshard_params(tparams_boxed, tgt)
    dvals = reshard_params(dparams_boxed, drf)
    return engine, tvals, dvals


def replan_split(prof_run, n_devices: int):
    """Re-run the allocation sweep after a topology change (thin wrapper so
    callers don't import the scheduler directly)."""
    from repro.core.scheduler import sweep_allocation

    return sweep_allocation(n_devices, prof_run)
