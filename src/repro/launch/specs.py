"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero device allocation (the dry-run contract).

``cell_specs(arch, shape, mesh)`` returns (step_fn, args_sds) such that
``jax.jit(step_fn).lower(*args_sds)`` is the production computation for that
(architecture × input-shape) cell:

  train_*    -> train_step(params, opt_state, batch)     fwd+bwd+AdamW
  prefill_*  -> prefill_step(params, batch)              full forward + cache
  decode_* / long_* -> serve_step(params, cache, tokens) one token vs cache

Shardings ride on the structs (jit reads them off the avals), so no
in_shardings plumbing is needed.
"""

from __future__ import annotations

import dataclasses
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, resolve_for_tp
from repro.configs.base import ModelConfig, ShapeCell
from repro.models.api import make_model
from repro.models.transformer import init_cache, init_model
from repro.optim import adamw_init
from repro.sharding import Param, sharding_for_tree, unbox

COMPUTE_DTYPE = "bfloat16"


# -----------------------------------------------------------------------------
# helpers
# -----------------------------------------------------------------------------


def _batch_axes(mesh: Mesh, dim: int) -> tuple[str, ...]:
    """('pod','data') filtered to axes that divide ``dim`` (drop from the
    right first, mirroring spec_for's partial fallback)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while axes:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size == 0:
            return axes
        axes = axes[:-1]
    return ()


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=NamedSharding(mesh, spec))


def dryrun_config(arch: str, mesh: Mesh) -> ModelConfig:
    """Published config, bf16 compute, head/ff dims padded for the mesh's TP
    degree (the paper's arbitrary-TP zero-padding, §4)."""
    cfg = get_config(arch)
    cfg = replace(cfg, dtype=COMPUTE_DTYPE, param_dtype=COMPUTE_DTYPE)
    return resolve_for_tp(cfg, mesh.shape.get("model", 1))


# -----------------------------------------------------------------------------
# parameter / optimizer / cache stand-ins
# -----------------------------------------------------------------------------


def param_specs(cfg: ModelConfig, mesh: Mesh):
    """eval_shape of init_model -> BOXED tree whose Param values are SDS with
    NamedShardings attached (the step functions expect boxed params)."""
    boxed = jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))

    def attach(p: Param):
        from repro.sharding import spec_for

        sh = NamedSharding(mesh, spec_for(mesh, p.axes, p.value.shape))
        return Param(jax.ShapeDtypeStruct(p.value.shape, p.value.dtype, sharding=sh), p.axes)

    return jax.tree.map(attach, boxed, is_leaf=lambda x: isinstance(x, Param)), boxed


def opt_specs(params_sds, mesh: Mesh):
    """AdamW state stand-ins: f32 moments/master share the param shardings."""
    def f32(v):
        return jax.ShapeDtypeStruct(v.shape, jnp.float32, sharding=v.sharding)

    from repro.optim.adamw import AdamWState

    return AdamWState(
        step=_sds((), jnp.int32, mesh, P()),
        mu=jax.tree.map(f32, params_sds),
        nu=jax.tree.map(f32, params_sds),
        master=jax.tree.map(f32, params_sds),
    )


_SEQ_KEYS = {"k": 2, "v": 2, "ckv": 2, "krope": 2, "ek": 2, "ev": 2}
_MODEL_DIM_KEYS = {"ssm": 2, "wkv": 2}  # heads dim shards over "model"


def cache_specs(cfg: ModelConfig, mesh: Mesh, B: int, S_max: int):
    """init_cache stand-ins: [U, B, S, ...] leaves; batch over (pod,data),
    cache sequence over "model" (kv_seq rule), SSM heads over "model"."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, B, S_max, jnp.dtype(COMPUTE_DTYPE)))
    msize = mesh.shape.get("model", 1)
    baxes = _batch_axes(mesh, B)

    def attach(path, v):
        if v.ndim == 0:  # "len"
            return jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=NamedSharding(mesh, P()))
        key = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                key = p.key
                break
        spec = [None] * v.ndim
        spec[1] = baxes if baxes else None
        if key in _SEQ_KEYS and v.shape[_SEQ_KEYS[key]] % msize == 0:
            spec[_SEQ_KEYS[key]] = "model"
        elif key in _MODEL_DIM_KEYS and v.shape[_MODEL_DIM_KEYS[key]] % msize == 0:
            spec[_MODEL_DIM_KEYS[key]] = "model"
        return jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=NamedSharding(mesh, P(*spec)))

    return jax.tree_util.tree_map_with_path(attach, shapes)


# -----------------------------------------------------------------------------
# per-cell input stand-ins
# -----------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh) -> dict:
    """Model-input stand-ins for train/prefill cells; stub frontends supply
    embeddings instead of token ids (assignment: modality frontend stubbed)."""
    B, S = shape.global_batch, shape.seq_len
    baxes = _batch_axes(mesh, B)
    bspec = baxes if baxes else None
    out: dict = {}
    if shape.kind == "train":
        if cfg.embed_inputs:
            out["tokens"] = _sds((B, S + 1), jnp.int32, mesh, P(bspec, None))
        else:  # audio stub frontend: precomputed frame embeddings + labels
            out["embeds"] = _sds((B, S, cfg.d_model), COMPUTE_DTYPE, mesh, P(bspec, None, None))
            out["labels"] = _sds((B, S), jnp.int32, mesh, P(bspec, None))
    else:  # prefill
        if cfg.embed_inputs:
            out["tokens"] = _sds((B, S), jnp.int32, mesh, P(bspec, None))
        else:
            out["embeds"] = _sds((B, S, cfg.d_model), COMPUTE_DTYPE, mesh, P(bspec, None, None))
    if cfg.n_enc_tokens:  # vlm stub frontend: precomputed patch embeddings
        out["enc"] = _sds((B, cfg.n_enc_tokens, cfg.d_model), COMPUTE_DTYPE, mesh, P(bspec, None, None))
    return out


def cell_specs(arch: str, shape_name: str, mesh: Mesh):
    """-> (step_fn, args_tuple_of_SDS, meta dict)."""
    from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step

    cfg = dryrun_config(arch, mesh)
    shape = SHAPES[shape_name]
    model = make_model(cfg)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch}

    if shape.kind == "train":
        params_sds, boxed = param_specs(cfg, mesh)
        opt_sds = opt_specs(params_sds, mesh)
        batch = batch_specs(cfg, shape, mesh)
        step = make_train_step(cfg, model)
        return step, (params_sds, opt_sds, batch), meta

    if shape.kind == "prefill":
        params_sds, _ = param_specs(cfg, mesh)
        batch = batch_specs(cfg, shape, mesh)
        step = make_prefill_step(cfg, model, S_max=shape.seq_len)
        return step, (params_sds, batch), meta

    # decode / long-context decode: one token against a seq_len cache
    B, S_max = shape.global_batch, shape.seq_len
    params_sds, _ = param_specs(cfg, mesh)
    cache = cache_specs(cfg, mesh, B, S_max)
    baxes = _batch_axes(mesh, B)
    tokens = _sds((B, 1), jnp.int32, mesh, P(baxes if baxes else None, None))
    step = make_decode_step(cfg, model, S_max=S_max)
    return step, (params_sds, cache, tokens), meta
