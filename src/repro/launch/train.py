"""End-to-end training driver (runnable on this CPU container with smoke
configs; the same code path the dry-run lowers at production scale).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt

Features exercised: sharded synthetic data, jitted train step (donated
state), atomic async checkpointing with auto-resume, step retry on transient
faults, loss logging.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.flags import override_flags
from repro.launch.steps import make_train_step
from repro.models.api import make_model
from repro.obs.clock import monotonic
from repro.optim import adamw_init
from repro.runtime import FaultConfig, retry_step
from repro.sharding import use_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = make_model(cfg)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((max(1, n_dev // args.mesh_model), args.mesh_model), ("data", "model"))

    ds = SyntheticLMDataset(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))
    step_fn = make_train_step(cfg, model, peak_lr=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    with use_mesh(mesh), override_flags(remat="none"):
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)

        start = 0
        cm = None
        if args.ckpt:
            cm = CheckpointManager(args.ckpt, keep=2)
            s, restored = cm.restore_latest((params, opt))
            if s is not None:
                start, (params, opt) = s + 1, restored
                print(f"resumed from step {s}")

        losses = []
        t0 = monotonic()
        for step in range(start, args.steps):
            host = ds.batch(step)
            feed = {"tokens": jnp.asarray(host["tokens"])}
            if cfg.n_enc_tokens:
                feed["enc"] = jnp.zeros((args.batch, cfg.n_enc_tokens, cfg.d_model), jnp.float32)
            if not cfg.embed_inputs:
                toks = host["tokens"]
                emb = jax.random.normal(jax.random.PRNGKey(1), (cfg.vocab_size, cfg.d_model)) * 0.02
                feed = {"embeds": jnp.asarray(emb)[toks[:, :-1]], "labels": jnp.asarray(toks[:, 1:])}

            def one():
                return jit_step(params, opt, feed)

            params, opt, loss = retry_step(one, FaultConfig())
            losses.append(float(loss))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = monotonic() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} ({dt:.1f}s)", flush=True)
            if cm and step and step % args.ckpt_every == 0:
                cm.save(step, (params, opt))
        if cm:
            cm.save(args.steps - 1, (params, opt), blocking=True)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} ({'improved' if last < first else 'NOT improved'})")
    return first, last


if __name__ == "__main__":
    main()
