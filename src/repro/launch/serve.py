"""Serving driver: asynchronous disaggregated speculative decoding
(the paper's system, end to end).

  PYTHONPATH=src python -m repro.launch.serve --requests 3 --max-new 48
  PYTHONPATH=src python -m repro.launch.serve --continuous --requests 8
  PYTHONPATH=src python -m repro.launch.serve --continuous --replicas 2

Runs the profile pass (paper §5.5: allocation split + expansion depth d),
then serves a deterministic request stream through SpecEngine and reports
decoding speed + compression ratio per request.  ``--continuous`` replaces
the one-batch-at-a-time replay with the continuous-batching runtime
(repro.serving): a seeded Poisson arrival trace is served through per-slot
request lifecycles — admissions backfill retiring slots mid-flight, per
request telemetry (TTFT, tok/s, acceptance, overlapping round lifetimes) is
printed, and each finished output is checked byte-identical against a solo
``generate()`` run (--no-verify to skip).  ``--replicas N`` shards the
continuous runtime over N SpecEngine replicas on disjoint device groups
(one global queue, least-loaded routing, per-replica + fleet telemetry).
``--async-rounds`` turns on asynchronous round disaggregation
(docs/async-disaggregation.md): each replica drafts round N+1's tree while
round N verifies, reconciling on a rejected lookahead seed — outputs stay
byte-identical to lockstep, and the traced ``draft_lookahead`` /
``verify_dispatch`` overlap in the phase breakdown is the evidence.
``--adaptive-depth`` turns on per-slot adaptive draft depth and
``--deadline-s X`` stamps every request with a finish deadline X seconds
after its arrival — EDF queueing, slack-aware routing, and an SLO
attainment report (docs/scheduling.md); outputs stay byte-identical.
``--trace-out trace.json --metrics-out metrics.json`` records per-round
phase spans (draft expand / verify / sync / reroot / absorb — viewable in
ui.perfetto.dev) and a metrics snapshot with the round-time decomposition
(repro.obs, docs/observability.md).
On this CPU container all device groups map to the same device (correctness
only); on a real slice ``--n-target``/``--n-draft`` select the disaggregated
split carved once per replica.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import SpecConfig, SpecEngine
from repro.core.scheduler import candidate_depths, profile_times
from repro.data import make_request_stream, make_request_trace
from repro.launch.mesh import make_serving_mesh
from repro.models.api import make_model
from repro.obs.clock import monotonic


def build_engine(target_arch: str, draft_arch: str, *, smoke=True, mode="parallel",
                 bs=8, w=4, c=2, d=2, max_new=48, S_max=512, n_target=6, n_draft=2,
                 peaked=True, replicas=1, async_rounds=False):
    """Build the serving engine(s).  With ``replicas > 1`` the device slice is
    carved into that many disjoint (target, draft) mesh pairs and one
    SpecEngine is built per pair; replicas whose mesh pair falls back to the
    same devices as replica 0 (the CPU container) REUSE replica 0's engine
    object — states are per-replica anyway, and sharing skips N-1 recompiles.
    Returns (engine | [engines], tparams, dparams, cfgT)."""
    cfgT = get_config(target_arch, smoke=smoke)
    cfgD = get_config(draft_arch, smoke=smoke)
    assert cfgT.vocab_size == cfgD.vocab_size, "draft/target must share a vocab"
    T, D = make_model(cfgT), make_model(cfgD)
    tp = T.init(jax.random.PRNGKey(0))
    dp = D.init(jax.random.PRNGKey(1))
    if peaked:
        # random-init logits are near-uniform; scale the lm_head so greedy
        # chains are peaked enough for realistic acceptance behaviour
        tp["lm_head"].value = tp["lm_head"].value * 4.0
        dp["lm_head"].value = dp["lm_head"].value * 4.0
    cfg = SpecConfig(bs=bs, w=w, c=c, d=d, mode=mode, max_new=max_new,
                     async_rounds=async_rounds)

    def mk(mesh_t, mesh_d):
        return SpecEngine(T, D, cfg, S_max_t=S_max, S_max_d=S_max,
                          mesh_target=mesh_t, mesh_draft=mesh_d)

    if replicas == 1:
        mesh_t, mesh_d = make_serving_mesh(n_target, n_draft)
        return mk(mesh_t, mesh_d), tp, dp, cfgT
    pairs = make_serving_mesh(n_target, n_draft, replicas=replicas)
    engines = [mk(*pairs[0])]
    for mt, md in pairs[1:]:
        same = (tuple(mt.devices.flat) == tuple(pairs[0][0].devices.flat)
                and tuple(md.devices.flat) == tuple(pairs[0][1].devices.flat))
        engines.append(engines[0] if same else mk(mt, md))
    return engines, tp, dp, cfgT


def run_continuous(args, engines, tp, dp, cfgT) -> None:
    """Continuous batching: serve a Poisson trace with per-slot lifecycles,
    on one engine or a sharded fleet (``--replicas N``).  With
    ``--trace-out``/``--metrics-out`` the run is instrumented end to end
    (repro.obs): per-round phase spans land in a Chrome/Perfetto-viewable
    ``trace.json`` (or JSONL), the metrics snapshot (per-replica round
    counters, accepted-depth histogram, TTFT, queue depth over time) plus
    the draft/verify/absorb round decomposition land in the metrics JSON."""
    from repro.obs import MetricsRegistry, Tracer, breakdown_report, phase_breakdown
    from repro.serving import (ContinuousBatchingRuntime, Request, RequestQueue,
                               SchedulerConfig, ShardedServingRuntime, WallClock)

    observed = bool(args.trace_out or args.metrics_out)
    tracer = Tracer() if observed else None
    metrics = MetricsRegistry() if observed else None
    scheduler = SchedulerConfig() if args.adaptive_depth else None

    trace = make_request_trace(
        cfgT.vocab_size, args.requests, rate_rps=args.rate,
        prompt_len=(max(4, args.prompt_len // 2), args.prompt_len),
        max_new=args.max_new, seed=0,
    )
    if isinstance(engines, list):
        rt = ShardedServingRuntime(
            engines, tp, dp, n_slots=args.slots,
            queue=RequestQueue(cap=args.queue_cap), clock=WallClock(),
            tracer=tracer, metrics=metrics, scheduler=scheduler,
        )
        label = f"{len(engines)} replicas x {args.slots} slots"
    else:
        rt = ContinuousBatchingRuntime(
            engines, tp, dp, n_slots=args.slots,
            queue=RequestQueue(cap=args.queue_cap), clock=WallClock(),
            tracer=tracer, metrics=metrics, scheduler=scheduler,
        )
        label = f"{args.slots} slots"
    accepted = rt.submit_trace(
        Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s, max_new=r.max_new,
                deadline_s=(r.arrival_s + args.deadline_s) if args.deadline_s else None)
        for r in trace
    )
    print(f"continuous: {accepted}/{len(trace)} requests accepted "
          f"({label}, Poisson rate {args.rate}/s, queue cap {args.queue_cap}"
          + (f", deadline {args.deadline_s}s" if args.deadline_s else "")
          + (", adaptive depth" if scheduler else "") + ")")
    t0 = monotonic()
    results = rt.run()
    wall = monotonic() - t0
    print(rt.report() if isinstance(engines, list) else rt.stats.report())
    total = sum(len(v) for v in results.values())
    print(f"wall: {total} tokens in {wall:.1f}s ({total/wall:.1f} tok/s incl. compile); "
          f"{rt.queue.rejected} shed by admission control")

    summary = rt.summary() if isinstance(engines, list) else rt.stats.summary()
    if summary["n_deadlined"]:
        print(f"SLO: {summary['slo_attainment']:.0%} of {summary['n_deadlined']} "
              f"deadlined requests met (slack p50 {summary['slack_p50_s']:+.3f}s "
              f"p10 {summary['slack_p10_s']:+.3f}s)")

    if observed:
        bd = phase_breakdown(tracer)
        print(breakdown_report(bd))
        if tracer.dropped:
            print(f"trace ring buffer dropped {tracer.dropped} events")
        if args.trace_out:
            path = tracer.write(args.trace_out)
            print(f"trace -> {path} (open in ui.perfetto.dev or chrome://tracing)")
        if args.metrics_out:
            slo = {k: summary[k] for k in ("n_deadlined", "slo_attainment",
                                           "slack_p50_s", "slack_p10_s")}
            path = metrics.write(args.metrics_out,
                                 extra={"phase_breakdown": bd, "slo": slo})
            print(f"metrics -> {path}")

    if args.verify:
        ref = engines[0] if isinstance(engines, list) else engines
        sess = ref.session(tp, dp)
        mismatches = 0
        for r in trace:
            if r.rid not in results:
                continue
            solo, _ = sess.generate(r.prompt.reshape(1, -1), max_new=r.max_new)
            ok = results[r.rid] == solo[0]
            mismatches += 0 if ok else 1
            where = ""
            if isinstance(engines, list):
                where = f" (replica {rt.replica_of(r.rid)})"
            print(f"verify req {r.rid}: "
                  f"{'byte-identical to solo generate()' if ok else 'MISMATCH'}{where}")
        if mismatches:
            raise SystemExit(f"{mismatches} request(s) diverged from solo generate()")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--target-arch", default="qwen2.5-14b")
    ap.add_argument("--draft-arch", default="qwen2.5-14b")
    ap.add_argument("--mode", choices=["parallel", "serial"], default="parallel")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--bs", type=int, default=8)
    ap.add_argument("--w", type=int, default=4)
    ap.add_argument("--d", type=int, default=0, help="0 = profile-derived")
    ap.add_argument("--n-target", type=int, default=6)
    ap.add_argument("--n-draft", type=int, default=2)
    ap.add_argument("--continuous", action="store_true",
                    help="serve a Poisson trace through the continuous-batching runtime")
    ap.add_argument("--async-rounds", action="store_true",
                    help="asynchronous round disaggregation: draft round N+1's "
                         "tree on the draft mesh while round N verifies on the "
                         "target mesh (parallel mode only; outputs stay "
                         "byte-identical to lockstep)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="continuous: SpecEngine replicas on disjoint device groups "
                         "(one global queue, depth-aware routing)")
    ap.add_argument("--slots", type=int, default=2, help="continuous: engine batch slots")
    ap.add_argument("--rate", type=float, default=2.0, help="continuous: Poisson arrival rate (req/s)")
    ap.add_argument("--queue-cap", type=int, default=64, help="continuous: admission-control queue cap")
    ap.add_argument("--adaptive-depth", action="store_true",
                    help="continuous: per-slot adaptive draft depth — each "
                         "slot's measured-acceptance EMA picks a depth bucket; "
                         "the round runs at the max over occupied slots "
                         "(docs/scheduling.md; outputs stay byte-identical)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="continuous: per-request finish deadline, seconds "
                         "after arrival (0 = best-effort); enables EDF "
                         "queueing, slack-aware routing, and SLO reporting")
    ap.add_argument("--no-verify", dest="verify", action="store_false",
                    help="continuous: skip byte-identical check vs solo generate()")
    ap.add_argument("--trace-out", default=None,
                    help="continuous: write phase spans here (.json = Chrome/"
                         "Perfetto traceEvents, .jsonl = span per line)")
    ap.add_argument("--metrics-out", default=None,
                    help="continuous: write the metrics snapshot + phase "
                         "breakdown here (.json; .prom = Prometheus text)")
    args = ap.parse_args(argv)

    replicas = args.replicas if args.continuous else 1
    eng, tp, dp, cfgT = build_engine(
        args.target_arch, args.draft_arch, mode=args.mode, bs=args.bs, w=args.w,
        d=args.d or 2, max_new=args.max_new, n_target=args.n_target, n_draft=args.n_draft,
        replicas=replicas, async_rounds=args.async_rounds,
    )
    eng0 = eng[0] if isinstance(eng, list) else eng

    # profile pass (paper §5.5): pick d from measured draft/target times
    if args.d == 0:
        import dataclasses

        prompt = np.zeros((1, args.prompt_len), np.int32)
        prof = eng0.profile(tp, dp, prompt)
        d_lo, d_hi = candidate_depths(prof)
        d_cfg = dataclasses.replace(eng0.cfg, d=d_lo)
        for e in set(eng) if isinstance(eng, list) else {eng}:
            e.cfg = d_cfg
        print(f"profile: t_draft={prof.t_draft_s*1e3:.1f}ms t_target={prof.t_target_s*1e3:.1f}ms "
              f"-> d in {{{d_lo},{d_hi}}}, using d={d_lo}")

    if args.continuous:
        run_continuous(args, eng, tp, dp, cfgT)
        return
    eng = eng0

    total_toks, total_s = 0, 0.0
    sess = eng.session(tp, dp)
    for i, prompt in enumerate(make_request_stream(cfgT.vocab_size, args.prompt_len, 1, args.requests)):
        t0 = monotonic()
        out, stats = sess.generate(prompt)
        dt = monotonic() - t0
        total_toks += len(out[0])
        total_s += dt
        print(f"req {i}: {len(out[0])} tokens in {dt:.2f}s "
              f"({len(out[0])/dt:.1f} tok/s), compression {stats.compression_ratio:.2f}")
    print(f"aggregate: {total_toks/total_s:.1f} tokens/s ({args.mode} mode)")


if __name__ == "__main__":
    main()
