"""Production meshes.

Single pod: (data=16, model=16) — 256 chips, one ICI domain.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the "pod" axis is pure
data parallelism over DCN (weights never shard across pods; only the gradient
all-reduce crosses the DCN boundary, optionally int8-compressed).

Functions, not module constants: importing this module must never touch jax
device state (the dry-run pins XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(n_target: int, n_draft: int, *, replicas: int = 1):
    """Disaggregated serving: disjoint (target, draft) TP submeshes
    (paper §3.1 GPU allocation), optionally carved ``replicas`` times for
    sharded serving — replica i owns devices
    ``[i*(n_target+n_draft), (i+1)*(n_target+n_draft))``, split target-first,
    so no device is shared across replicas or across the draft/target roles.

    Returns one ``(target_mesh, draft_mesh)`` pair for ``replicas == 1``
    (the historical signature) or a list of ``replicas`` pairs otherwise.
    On hosts with fewer than ``n_target + n_draft`` devices, EVERY pair
    falls back to one shared device (the CPU container — correctness-only).
    A partial fit — enough devices for some replicas but not all — raises
    instead of silently overlapping later replicas onto device 0, which
    would defeat the sharding it claims to provide.
    """
    from jax.sharding import Mesh
    import numpy as np

    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    devs = jax.devices()
    group = n_target + n_draft

    if len(devs) < group:  # all-or-nothing fallback: shared single device
        def shared():
            m = Mesh(np.array(devs[:1]), ("model",))
            return m, m

        return shared() if replicas == 1 else [shared() for _ in range(replicas)]
    if len(devs) < group * replicas:
        raise ValueError(
            f"{len(devs)} devices cannot host {replicas} disjoint replicas of "
            f"{group} devices ({n_target} target + {n_draft} draft) — lower "
            f"the replica count or the per-replica device split")

    def carve(i: int):
        base = i * group
        tgt = Mesh(np.array(devs[base : base + n_target]), ("model",))
        drf = Mesh(np.array(devs[base + n_target : base + group]), ("model",))
        return tgt, drf

    if replicas == 1:
        return carve(0)
    return [carve(i) for i in range(replicas)]


def host_device_mesh(model: int = 1, data: int = 1):
    """Small explicit mesh for tests (uses however many devices exist)."""
    return jax.make_mesh((data, model), ("data", "model"))
