"""Production meshes.

Single pod: (data=16, model=16) — 256 chips, one ICI domain.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the "pod" axis is pure
data parallelism over DCN (weights never shard across pods; only the gradient
all-reduce crosses the DCN boundary, optionally int8-compressed).

Functions, not module constants: importing this module must never touch jax
device state (the dry-run pins XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(n_target: int, n_draft: int):
    """Disaggregated serving: disjoint (target, draft) TP submeshes
    (paper §3.1 GPU allocation).  Falls back to one shared device on the
    CPU container (correctness-only)."""
    from jax.sharding import Mesh
    import numpy as np

    devs = jax.devices()
    if len(devs) < n_target + n_draft:
        m = Mesh(np.array(devs[:1]), ("model",))
        return m, m
    tgt = Mesh(np.array(devs[:n_target]), ("model",))
    drf = Mesh(np.array(devs[n_target : n_target + n_draft]), ("model",))
    return tgt, drf


def host_device_mesh(model: int = 1, data: int = 1):
    """Small explicit mesh for tests (uses however many devices exist)."""
    return jax.make_mesh((data, model), ("data", "model"))
