"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / peak_FLOP/s            (per-chip: the compiled
memory term     = HLO_bytes / HBM_bw                  SPMD module is one
collective term = collective_bytes / link_bw          participant's program)

``cost_analysis`` supplies flops / bytes accessed; collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text: build an instruction →
result-type map, then sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async ``-start`` forms
counted once, ``-done`` skipped).

DTYPE CORRECTION (documented in EXPERIMENTS.md §Roofline): the CPU backend
cannot compute in bf16 and converts model tensors to f32 before GEMMs and
collectives, so f32 byte counts from the CPU-compiled module overstate what a
TPU (native bf16) module moves by 2x.  We therefore report raw numbers AND a
corrected variant with f32 bytes scaled by 0.5; genuinely-f32 tensors
(optimizer masters, softmax statistics) are under-counted by the correction,
bounded by their small share of traffic.  Corrected values drive the
bottleneck classification.

Hardware model (assignment constants, TPU v5e-class):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link (ICI)
DTYPE_CORRECTION = 0.5  # f32-on-CPU -> bf16-on-TPU

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(type_str: str) -> tuple[float, float]:
    """-> (raw_bytes, corrected_bytes) for a (possibly tuple) HLO type."""
    raw = corr = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dtype]
        raw += b
        corr += b * (DTYPE_CORRECTION if dtype == "f32" else 1.0)
    return raw, corr


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict  # raw operand bytes per op kind
    corrected_by_kind: dict  # f32 scaled to bf16
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_corrected(self) -> float:
        return sum(self.corrected_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in a per-participant SPMD module."""
    # pass 1: instruction name -> result type string
    types: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            types[m.group(1)] = m.group(2)

    bytes_by: dict[str, float] = {}
    corr_by: dict[str, float] = {}
    count_by: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        base = op.replace("-start", "") if op.endswith("-start") else op
        if base not in _COLL_OPS or op.endswith("-done"):
            continue
        # operand list: everything inside the call parens on this line
        call = line.split(f"{op}(", 1)[1]
        operands = call.split(")", 1)[0]
        raw = corr = 0.0
        for name in _OPERAND_RE.findall(operands):
            t = types.get(name)
            if t is None:
                continue
            r, c = _type_bytes(t)
            raw += r
            corr += c
        bytes_by[base] = bytes_by.get(base, 0.0) + raw
        corr_by[base] = corr_by.get(base, 0.0) + corr
        count_by[base] = count_by.get(base, 0) + 1
    return CollectiveStats(bytes_by, corr_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops: float  # per-chip HLO flops
    hbm_bytes_raw: float  # per-chip bytes accessed (CPU-compiled, f32-inflated)
    hbm_bytes: float  # dtype-corrected
    collective_bytes_raw: float
    collective_bytes: float  # dtype-corrected
    t_compute_s: float
    t_memory_s: float
    t_collective_s: float
    bottleneck: str
    model_flops: float  # 6·N·D (train) or 2·N_active·D (serve), per chip
    useful_fraction: float  # model_flops / flops
    roofline_fraction: float  # ideal model-flops time / dominant term

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms_from_module(mc, model_flops_per_chip: float) -> Roofline:
    """Terms from a loop-aware hlo_parse.ModuleCost (trip-scaled)."""
    flops = mc.flops
    hbm_raw, hbm = mc.bytes_raw, mc.bytes
    cb_raw, cb = mc.collective_bytes_raw, mc.collective_bytes
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = cb / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    t_ideal = model_flops_per_chip / PEAK_FLOPS
    dominant = max(terms.values())
    return Roofline(
        flops=flops, hbm_bytes_raw=hbm_raw, hbm_bytes=hbm,
        collective_bytes_raw=cb_raw, collective_bytes=cb,
        t_compute_s=t_c, t_memory_s=t_m, t_collective_s=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops_per_chip,
        useful_fraction=(model_flops_per_chip / flops) if flops else 0.0,
        roofline_fraction=(t_ideal / dominant) if dominant > 0 else 0.0,
    )


def roofline_terms(cost: dict, coll: CollectiveStats, model_flops_per_chip: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm_raw = float(cost.get("bytes accessed", 0.0))
    hbm = hbm_raw * DTYPE_CORRECTION
    cb_raw = float(coll.total_bytes)
    cb = float(coll.total_corrected)
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = cb / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    t_ideal = model_flops_per_chip / PEAK_FLOPS
    dominant = max(terms.values())
    return Roofline(
        flops=flops, hbm_bytes_raw=hbm_raw, hbm_bytes=hbm,
        collective_bytes_raw=cb_raw, collective_bytes=cb,
        t_compute_s=t_c, t_memory_s=t_m, t_collective_s=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops_per_chip,
        useful_fraction=(model_flops_per_chip / flops) if flops else 0.0,
        roofline_fraction=(t_ideal / dominant) if dominant > 0 else 0.0,
    )


def model_flops_per_chip(cfg, shape, n_chips: int) -> float:
    """MODEL_FLOPS for the cell, divided over chips.

    Parameter part: 6·N·D (train fwd+bwd) / 2·N_active·D (serve forward), D =
    tokens processed.  Attention part (dominant at long context): per layer
    4·T_q·S_kv·Hq·hd forward (qk + pv), ×3 with backward; causal prefill/train
    halves S_kv on average.  MoE uses active params (routed top-k + shared)."""
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    d_attn = cfg.n_heads * cfg.head_dim
    n_attn_layers = sum(1 for k in cfg.layer_kinds if k in ("dense", "moe", "cross"))
    if cfg.shared_attn_every:
        n_attn_layers += (cfg.n_layers - cfg.first_k_dense) // cfg.shared_attn_every
    if shape.kind == "train":
        total = 6.0 * n_active * (B * S)
        total += 3 * 4.0 * B * (S * S / 2) * d_attn * n_attn_layers
    elif shape.kind == "prefill":
        total = 2.0 * n_active * (B * S)
        total += 4.0 * B * (S * S / 2) * d_attn * n_attn_layers
    else:  # decode: one token per sequence against an S-row cache
        total = 2.0 * n_active * B
        total += 4.0 * B * S * d_attn * n_attn_layers
    return total / n_chips
