import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove memory fit, and extract roofline terms.

MUST be run as its own process (the two lines above pin 512 placeholder host
devices before jax initializes — never set that globally).

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1

Results land in benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json and
feed EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse
import json
import traceback

import jax

from repro.configs import ASSIGNED, SHAPES, cell_applicable, get_config
from repro.flags import override_flags
from repro.launch.hlo_parse import analyze, compiled_cost
from repro.launch.hlo_stats import model_flops_per_chip, roofline_terms_from_module
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cell_specs, dryrun_config
from repro.obs.clock import monotonic
from repro.sharding import use_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool, flag_overrides: dict | None = None):
    """Lower + compile one cell; returns the result record (raises on failure)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    shape = SHAPES[shape_name]
    cfg_pub = get_config(arch)
    ok, why = cell_applicable(cfg_pub, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": "pod2" if multi_pod else "pod1",
                "status": "skipped", "reason": why}

    flags = dict(
        scan_layers=True,
        remat="full" if shape.kind == "train" else "none",
        seq_shard_acts=shape.kind in ("train", "prefill"),
    )
    flags.update(flag_overrides or {})

    # monotonic, not time.time(): an NTP step mid-compile used to be able to
    # produce negative lower/compile durations in the dry-run records
    t0 = monotonic()
    with use_mesh(mesh), override_flags(**flags):
        step, args, meta = cell_specs(arch, shape_name, mesh)
        donate = {"train": (0, 1), "prefill": (), "decode": (1,)}[shape.kind]
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = monotonic() - t0
        compiled = lowered.compile()
        t_compile = monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled_cost(compiled)
    mc = analyze(compiled.as_text())  # loop-aware, trip-scaled accounting
    cfg = dryrun_config(arch, mesh)
    rf = roofline_terms_from_module(mc, model_flops_per_chip(cfg, shape, n_chips))

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2" if multi_pod else "pod1",
        "status": "ok",
        "n_chips": int(n_chips),
        "flags": flags,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ),
        },
        "collectives": {
            "bytes_by_kind": mc.collective,
            "bytes_by_kind_raw": mc.collective_raw,
            "count_by_kind": mc.collective_count,
        },
        "loop_trips": mc.loop_trips,
        "cost_analysis_raw": {  # XLA aggregate (loop bodies counted once)
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "roofline": rf.as_dict(),
    }
    return rec


def save(rec: dict, out_dir: str):
    d = os.path.join(out_dir, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def summarize(rec: dict) -> str:
    if rec["status"] != "ok":
        return f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']}: SKIP ({rec['reason'][:60]})"
    r = rec["roofline"]
    gib = rec["memory"]["peak_bytes_per_device"] / 2**30
    return (
        f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']}: ok "
        f"compile={rec['compile_s']:.0f}s mem/dev={gib:.2f}GiB "
        f"t_comp={r['t_compute_s']:.2e} t_mem={r['t_memory_s']:.2e} "
        f"t_coll={r['t_collective_s']:.2e} -> {r['bottleneck']}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--all", action="store_true", help="every arch x shape x mesh")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--flag", action="append", default=[],
                    help="flags override k=v (e.g. seq_shard_acts=False)")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch == "all") else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape == "all") else [args.shape]
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]
    overrides = {}
    for kv in args.flag:
        k, v = kv.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(v, v if not v.isdigit() else int(v))

    failures = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "pod2" if multi_pod else "pod1"
                path = os.path.join(args.out, mesh_name, f"{arch}__{shape}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"{arch:22s} {shape:12s} {mesh_name}: cached")
                    continue
                try:
                    rec = run_cell(arch, shape, multi_pod, overrides)
                except Exception as e:  # noqa: BLE001 — report, continue
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "fail", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    failures.append((arch, shape, mesh_name))
                save(rec, args.out)
                print(summarize(rec) if rec["status"] != "fail"
                      else f"{arch:22s} {shape:12s} {mesh_name}: FAIL {rec['error'][:100]}",
                      flush=True)

    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall cells ok")


if __name__ == "__main__":
    main()
