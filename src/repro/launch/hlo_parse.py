"""Loop-aware cost model over optimized HLO text.

``jax.stages.Compiled.cost_analysis()`` sums instruction costs with every
computation counted ONCE — a scan-over-layers body therefore contributes a
single iteration.  For roofline terms we need trip-scaled totals, so this
module parses the HLO module text into computation blocks, walks the call
graph (while bodies ×trip count from ``backend_config known_trip_count``,
calls, conditionals), and accumulates:

  flops            — exact for dot ops from dimension numbers
  bytes            — fusion-level traffic: operands + result of every
                     non-free top-level instruction (fusion internals are
                     register/VMEM-resident, matching XLA's own model)
  collective bytes — operand sizes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute

each scaled by the product of enclosing loop trip counts.  Per-kind
collective tables feed the §Perf analysis (redundant-collective hunting).

DTYPE CORRECTION: the CPU backend upcasts bf16 model tensors to f32 before
GEMMs/collectives, so the ``bytes``/``collective`` fields scale f32 sizes by
0.5 (what native-bf16 TPU would move); ``*_raw`` keeps the uncorrected sums.
Genuinely-f32 tensors (optimizer masters, softmax stats) are under-counted by
the correction; they are a small share of traffic.

The parser is text-based (the AOT API exposes no structured HLO) and
tolerant: unknown opcodes contribute bytes but no flops.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}

# opcodes whose called computations execute as part of the caller's schedule
_TRAVERSE_OPS = {"while", "call", "conditional", "async-start"}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]\w*?)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+((?:\([^)]*\))|(?:[a-z]\w*\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def compiled_cost(compiled) -> dict:
    """jax-version compat: ``Compiled.cost_analysis()`` returned ``[dict]``
    before jax unified it to a plain dict.  Single shim for every caller."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def _sizes(type_str: str) -> tuple[float, float]:
    """(raw_bytes, corrected_bytes) over a possibly-tuple type string."""
    raw = corr = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dtype]
        raw += b
        corr += b * (0.5 if dtype == "f32" else 1.0)
    return raw, corr


def _elems(type_str: str) -> float:
    n_total = 0.0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attrs (text after the open paren)

    def operand_names(self) -> list[str]:
        return _OPERAND_RE.findall(self.rest.split(")", 1)[0])


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


def parse_module(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_HDR_RE.match(line)
                if m and "->" in line:
                    cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
                    comps[cur.name] = cur
                    if cur.is_entry:
                        entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            cur.instrs.append(Instr(mi.group(1), mi.group(2), mi.group(3), mi.group(4)))
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _dot_flops(instr: Instr, types: dict[str, str]) -> float:
    res_elems = _elems(instr.type_str)
    ops = instr.operand_names()
    if not ops:
        return 0.0
    m = _SHAPE_RE.search(types.get(ops[0], ""))
    if not m:
        return 0.0
    lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contraction = 1
    if mc:
        for d in mc.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contraction *= lhs_dims[int(d)]
    return 2.0 * res_elems * contraction


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes_raw: float
    bytes: float  # dtype-corrected
    collective_raw: dict
    collective: dict  # dtype-corrected
    collective_count: dict
    loop_trips: dict  # while instr -> trip count

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective.values())

    @property
    def collective_bytes_raw(self) -> float:
        return sum(self.collective_raw.values())


def _fusion_param_charges(comp: Computation) -> dict[int, float]:
    """Per-parameter corrected byte charges for a fused computation.

    A fusion operand that is only (dynamic-)sliced inside the fusion is read
    at the SLICE size, not the full operand size (the scan-over-layers cache
    stack would otherwise be charged in full for every per-layer slice).
    Returns {param_index: charged_bytes} for params that qualify.
    """
    params: dict[str, int] = {}
    for i in comp.instrs:
        if i.opcode == "parameter":
            m = re.match(r"\s*(\d+)", i.rest)
            if m:
                params[i.name] = int(m.group(1))
    if not params:
        return {}
    uses: dict[str, list] = {name: [] for name in params}
    for i in comp.instrs:
        if i.opcode == "parameter":
            continue
        for on in i.operand_names():
            if on in uses:
                uses[on].append(i)
    out: dict[int, float] = {}
    for name, idx in params.items():
        insts = uses[name]
        if insts and all(u.opcode in ("dynamic-slice", "slice") for u in insts):
            charged = 0.0
            for u in insts:
                _, cb = _sizes(u.type_str)
                charged += cb
            out[idx] = charged
    return out


def analyze(text: str) -> ModuleCost:
    comps, entry = parse_module(text)
    types: dict[str, str] = {}
    for c in comps.values():
        for i in c.instrs:
            types[i.name] = i.type_str

    charges_cache: dict[str, dict] = {}

    def fusion_charges(called: str):
        if called not in charges_cache:
            comp = comps.get(called)
            charges_cache[called] = _fusion_param_charges(comp) if comp else {}
        return charges_cache[called]

    convert_cache: dict[str, bool] = {}

    def is_convert_only(called: str) -> bool:
        """Fusions that ONLY convert dtype (wrapped_convert_*): pure bf16<->f32
        reconciliation synthesized by the CPU backend; native-bf16 TPUs never
        materialize them.  Excluded from corrected bytes (kept in raw)."""
        if called not in convert_cache:
            comp = comps.get(called)
            ok = False
            if comp:
                real = [i for i in comp.instrs if i.opcode not in _FREE_OPS]
                ok = bool(real) and all(i.opcode in ("convert", "copy", "bitcast-convert")
                                        for i in real)
            convert_cache[called] = ok
        return convert_cache[called]

    # multiplier propagation from entry through while/call/conditional
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    loop_trips: dict[str, int] = {}
    queue = [entry]
    visited_edges = set()
    while queue:
        cname = queue.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for i in comp.instrs:
            if i.opcode not in _TRAVERSE_OPS:
                continue
            attrs = i.rest
            if i.opcode == "while":
                mt = _TRIP_RE.search(attrs)
                trips = int(mt.group(1)) if mt else 1
                loop_trips[i.name] = trips
                mb = re.search(r"body=%?([\w.\-]+)", attrs)
                if mb and (cname, i.name, mb.group(1)) not in visited_edges:
                    visited_edges.add((cname, i.name, mb.group(1)))
                    mult[mb.group(1)] += m * trips
                    queue.append(mb.group(1))
            else:
                for key in ("to_apply", "branch_computations", "true_computation",
                            "false_computation", "called_computations"):
                    mk = re.search(key + r"=\{?%?([\w.\-,%\s]+?)\}?[,)]", attrs)
                    if not mk:
                        continue
                    for name in re.findall(r"[\w.\-]+", mk.group(1)):
                        if name in comps and (cname, i.name, name) not in visited_edges:
                            visited_edges.add((cname, i.name, name))
                            mult[name] += m
                            queue.append(name)

    flops = 0.0
    bytes_raw = bytes_corr = 0.0
    coll_raw: dict[str, float] = defaultdict(float)
    coll_corr: dict[str, float] = defaultdict(float)
    coll_count: dict[str, int] = defaultdict(int)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for i in comp.instrs:
            if i.opcode in _FREE_OPS:
                continue
            rb, cb = _sizes(i.type_str)
            charges = {}
            convert_only = i.opcode == "convert"
            if i.opcode == "fusion":
                mk = re.search(r"calls=%?([\w.\-]+)", i.rest)
                if mk:
                    charges = fusion_charges(mk.group(1))
                    convert_only = is_convert_only(mk.group(1))
            ob_raw = ob_corr = 0.0
            for pos, on in enumerate(i.operand_names()):
                t = types.get(on)
                if not t:
                    continue
                if pos in charges:  # sliced-only fusion operand
                    ob_raw += charges[pos] * 2  # raw ~ 2x corrected (f32)
                    ob_corr += charges[pos]
                    continue
                r, c = _sizes(t)
                ob_raw += r
                ob_corr += c
            bytes_raw += m * (rb + ob_raw)
            if not convert_only:
                bytes_corr += m * (cb + ob_corr)
            if i.opcode == "dot":
                flops += m * _dot_flops(i, types)
            base = i.opcode.replace("-start", "")
            if base in COLLECTIVE_OPS and not i.opcode.endswith("-done"):
                raw = corr = 0.0
                for on in i.operand_names():
                    t = types.get(on)
                    if t:
                        r, c = _sizes(t)
                        raw += r
                        corr += c
                coll_raw[base] += m * raw
                coll_corr[base] += m * corr
                coll_count[base] += int(m)

    return ModuleCost(
        flops=flops, bytes_raw=bytes_raw, bytes=bytes_corr,
        collective_raw=dict(coll_raw), collective=dict(coll_corr),
        collective_count=dict(coll_count), loop_trips=loop_trips,
    )
