"""Production step functions (what the dry-run lowers and the drivers run).

All three are pure functions of (params, state, batch) suitable for
``jax.jit(..., donate_argnums=...)`` under a mesh; model-internal sharding
constraints (sharding/rules.py) plus the input shardings riding on the avals
fully determine the SPMD partitioning.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy_loss
from repro.optim import adamw_update, warmup_cosine


def make_train_step(cfg, model, *, peak_lr=3e-4, warmup_steps=100, total_steps=10_000,
                    grad_compress_pod: bool = False):
    """fwd + CE loss + bwd + AdamW.  Batch: {"tokens": [B, S+1]} or the stub-
    frontend form {"embeds": [B, S, d], "labels": [B, S]} (+ optional "enc")."""

    def train_step(params, opt_state, batch):
        if "tokens" in batch:
            inputs, labels = batch["tokens"][:, :-1], batch["tokens"][:, 1:]
            feed = dict(tokens=inputs)
        else:
            labels = batch["labels"]
            feed = dict(embeds=batch["embeds"])
        if "enc" in batch:
            feed["enc"] = batch["enc"]

        def loss_fn(p):
            logits = model.forward_train(p, **feed)
            return cross_entropy_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if grad_compress_pod:
            from repro.optim.compression import pod_allreduce_compressed
            from repro.sharding import get_mesh, shard_map
            from jax.sharding import PartitionSpec as P

            mesh = get_mesh()
            if mesh is not None and "pod" in mesh.axis_names:
                # int8-compressed DCN gradient exchange (optim/compression.py)
                grads = jax.tree.map(
                    lambda g: shard_map(
                        lambda x: pod_allreduce_compressed(x, "pod"),
                        mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False,
                    )(g),
                    grads,
                )
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr,
                           warmup_steps=warmup_steps, total_steps=total_steps)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr)
        return new_params, new_opt, loss

    return train_step


def make_prefill_step(cfg, model, *, S_max: int):
    """Full forward populating the KV cache; emits (next-token ids, cache)."""

    def prefill_step(params, batch):
        feed = {}
        if "tokens" in batch:
            feed["tokens"] = batch["tokens"]
        else:
            feed["embeds"] = batch["embeds"]
        if "enc" in batch:
            feed["enc"] = batch["enc"]
        logits, cache = model.prefill(params, S_max=S_max, **feed)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    return prefill_step


def make_decode_step(cfg, model, *, S_max: int):
    """One new token against a cache of S_max rows (decode_* / long_* cells)."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens, S_max)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    return serve_step


def make_spec_verify_step(cfg, model, *, S_max: int, bs: int):
    """The paper's target-side verification forward: ``bs`` tree nodes under a
    non-square mask (used by the spec-decoding benchmark cells, beyond the
    assignment's required decode shape)."""

    def verify_step(params, cache, tokens, positions, rows, mask):
        logits, cache = model.spec_forward(params, cache, tokens, positions, rows, mask)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return verify_step
