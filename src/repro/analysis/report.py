"""Reporters: human text and machine JSON, sharing one summary shape."""

from __future__ import annotations

import collections
import json

from repro.analysis.core import REGISTRY, Finding


def summarize(findings: list[Finding], stale: list[str]) -> dict:
    by_rule = collections.Counter(f.rule for f in findings if not f.baselined)
    return {
        "total": len(findings),
        "new": sum(1 for f in findings if not f.baselined),
        "baselined": sum(1 for f in findings if f.baselined),
        "by_rule": dict(sorted(by_rule.items())),
        "stale_baseline": stale,
    }


def render_text(findings: list[Finding], stale: list[str], n_files: int) -> str:
    lines = [f.format() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.col, f.rule))]
    for fp in stale:
        lines.append(f"baseline: stale entry {fp} matches no finding — "
                     f"remove it (or restore the code it covered)")
    s = summarize(findings, stale)
    verdict = "clean" if not s["new"] and not stale else "FAIL"
    lines.append(
        f"repro.analysis: {n_files} files, {s['new']} new finding(s), "
        f"{s['baselined']} baselined, {len(stale)} stale baseline "
        f"entr{'y' if len(stale) == 1 else 'ies'} -> {verdict}")
    return "\n".join(lines)


def render_json(findings: list[Finding], stale: list[str], n_files: int) -> str:
    doc = {
        "version": 1,
        "files": n_files,
        "findings": [f.to_json() for f in sorted(
            findings, key=lambda f: (f.path, f.line, f.col, f.rule))],
        "summary": summarize(findings, stale),
        "rules": {r.name: r.description for r in REGISTRY.values()},
    }
    return json.dumps(doc, indent=1)
