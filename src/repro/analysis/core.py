"""Framework core: findings, the rule registry, suppressions, the driver.

The pass is deliberately pure-stdlib (``ast`` only, no jax import): it must
run in a bare CI job in milliseconds and must never initialize a device
backend just to lint the tree.

One ``FileContext`` is built per analyzed file (one parse, one suppression
scan) and every registered rule is dispatched over it.  Findings carry a
*fingerprint* — rule + root-relative path + the stripped source line + an
occurrence index — so the baseline survives unrelated line-number drift but
goes stale (loudly) when the flagged code itself changes or disappears.

Suppressions: ``# repro: disable=RULE[,RULE...] — reason`` on the violating
line, or on a standalone comment line directly above it.  A suppression that
matches no finding is itself reported (rule ``UNUSED-SUPPRESS``), so stale
escapes cannot accumulate silently.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import os
import re
import tokenize


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # root-relative posix path (stable across machines)
    line: int
    col: int
    message: str
    snippet: str = ""  # stripped source line, the fingerprint's anchor
    occurrence: int = 0  # index among identical (rule, path, snippet)
    baselined: bool = False

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha1(
            f"{self.rule}|{self.path}|{self.snippet}".encode()
        ).hexdigest()[:16]
        return f"{h}#{self.occurrence}"

    def format(self) -> str:
        tag = " [baselined]" if self.baselined else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}{tag}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "fingerprint": self.fingerprint,
            "baselined": self.baselined,
        }


_DISABLE_RE = re.compile(r"#\s*repro:\s*disable=([A-Z0-9_,\-]+)")


@dataclasses.dataclass
class _Suppression:
    line: int  # comment's own line
    rules: tuple[str, ...]
    used: bool = False


class FileContext:
    """One file's parse + line table + suppression table, shared by rules."""

    def __init__(self, abspath: str, relpath: str, source: str):
        self.abspath = abspath
        self.path = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)  # may raise SyntaxError
        self.suppressions = self._scan_suppressions(source)

    @staticmethod
    def _scan_suppressions(source: str) -> list[_Suppression]:
        out = []
        import io

        try:
            for tok in tokenize.generate_tokens(io.StringIO(source).readline):
                if tok.type != tokenize.COMMENT:
                    continue
                m = _DISABLE_RE.search(tok.string)
                if m:
                    rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
                    out.append(_Suppression(line=tok.start[0], rules=rules))
        except tokenize.TokenError:  # unterminated string etc.: parse will flag it
            pass
        return out

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def is_suppressed(self, rule: str, lineno: int) -> bool:
        """Same-line or directly-above suppression; marks the escape used."""
        hit = False
        for sup in self.suppressions:
            if rule in sup.rules and sup.line in (lineno, lineno - 1):
                sup.used = hit = True
        return hit

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(rule=rule, path=self.path, line=line, col=col,
                       message=message, snippet=self.line_text(line))


class Rule:
    """Base rule: subclass, set ``name``/``description``, implement check()."""

    name = "RULE"
    description = ""

    def applies_to(self, relpath: str) -> bool:
        return True

    def check(self, ctx: FileContext, project) -> list[Finding]:
        raise NotImplementedError


REGISTRY: dict[str, Rule] = {}


def register(cls):
    """Class decorator adding one rule instance to the global registry."""
    inst = cls()
    REGISTRY[inst.name] = inst
    return cls


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def iter_py_files(paths: list[str]):
    """Yield (abspath, root) pairs; ``root`` is the scan root the file was
    found under (fingerprint paths are relative to it, so the same tree
    scanned from anywhere produces the same fingerprints)."""
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            yield p, os.path.dirname(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn), p


def analyze_file(abspath: str, root: str, project, rules=None) -> list[Finding]:
    relpath = os.path.relpath(abspath, root).replace(os.sep, "/")
    with open(abspath, encoding="utf-8") as f:
        source = f.read()
    try:
        ctx = FileContext(abspath, relpath, source)
    except SyntaxError as e:
        return [Finding(rule="PARSE", path=relpath, line=e.lineno or 1,
                        col=e.offset or 0, message=f"syntax error: {e.msg}",
                        snippet="")]
    findings: list[Finding] = []
    for rule in (rules if rules is not None else REGISTRY.values()):
        if not rule.applies_to(relpath):
            continue
        for f_ in rule.check(ctx, project):
            if not ctx.is_suppressed(f_.rule, f_.line):
                findings.append(f_)
    for sup in ctx.suppressions:
        if not sup.used:
            findings.append(Finding(
                rule="UNUSED-SUPPRESS", path=relpath, line=sup.line, col=0,
                message=f"suppression for {','.join(sup.rules)} matches no "
                        f"finding — delete it",
                snippet=ctx.line_text(sup.line)))
    return _index_occurrences(findings)


def _index_occurrences(findings: list[Finding]) -> list[Finding]:
    """Disambiguate identical (rule, path, snippet) findings by order."""
    seen: dict[tuple, int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.line, f.col, f.rule)):
        key = (f.rule, f.path, f.snippet)
        n = seen.get(key, 0)
        seen[key] = n + 1
        out.append(dataclasses.replace(f, occurrence=n))
    return out


def analyze_paths(paths: list[str], project, rules=None) -> list[Finding]:
    out: list[Finding] = []
    for abspath, root in iter_py_files(paths):
        out.extend(analyze_file(abspath, root, project, rules))
    return out


# ---------------------------------------------------------------------------
# shared AST helpers used by several rules
# ---------------------------------------------------------------------------


def qualname(node: ast.AST) -> str | None:
    """Dotted name of a Name/Attribute chain (``jax.lax.psum``), else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ImportMap(ast.NodeVisitor):
    """Local name -> canonical dotted module/symbol path for a module."""

    def __init__(self, tree: ast.Module):
        self.names: dict[str, str] = {}
        self.visit(tree)

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.names[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0]
            )

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module is None:
            return
        for a in node.names:
            self.names[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> str | None:
        """Canonical dotted path of a Name/Attribute reference, following the
        module's import aliases (``pc()`` -> ``time.perf_counter``)."""
        q = qualname(node)
        if q is None:
            return None
        head, _, rest = q.partition(".")
        base = self.names.get(head)
        if base is None:
            return q
        return f"{base}.{rest}" if rest else base
