"""Project context: the declared mesh/logical axis vocabulary.

AXIS findings are only as good as the set they check against, so the
context is extracted from the repo's own declarations — the
``DEFAULT_RULES`` table in ``sharding/rules.py`` (keys = logical axes,
values = the mesh axes they map onto) and the mesh constructions in
``launch/mesh.py`` (``jax.make_mesh(shape, axes)`` / ``Mesh(devs, axes)``
axis tuples).  Editing either file updates the checker automatically; the
fallback constants below only cover scans (e.g. test fixtures) that don't
contain those files.
"""

from __future__ import annotations

import ast
import dataclasses
import os

# fallbacks mirroring src/repro/sharding/rules.py + launch/mesh.py, used only
# when the scanned tree does not carry its own declarations
FALLBACK_MESH_AXES = frozenset({"model", "data", "pod"})
FALLBACK_LOGICAL_AXES = frozenset({
    "batch", "seq", "act_seq", "act_embed", "embed", "heads", "kv_heads",
    "head_dim", "qk_dim", "ff", "vocab", "experts", "experts_ep", "inner",
    "state", "conv", "lora", "unit", "layers", "kv_seq", "cache_batch",
})


@dataclasses.dataclass
class ProjectContext:
    mesh_axes: frozenset[str] = FALLBACK_MESH_AXES
    logical_axes: frozenset[str] = FALLBACK_LOGICAL_AXES
    rules_file: str | None = None  # where the declarations were found
    mesh_file: str | None = None


def _str_consts(node: ast.AST):
    """Every string constant anywhere under ``node``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub.value


def _extract_rules_table(path: str):
    """(logical axes, mesh axes) from a ``DEFAULT_RULES = {...}`` literal."""
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    logical, mesh = set(), set()
    for node in ast.walk(tree):
        # plain or annotated assignment (DEFAULT_RULES: dict[...] = {...})
        if isinstance(node, ast.Assign) and node.targets:
            target = node.targets[0]
        elif isinstance(node, ast.AnnAssign):
            target = node.target
        else:
            continue
        if not (isinstance(target, ast.Name) and target.id == "DEFAULT_RULES"
                and isinstance(node.value, ast.Dict)):
            continue
        for k, v in zip(node.value.keys, node.value.values):
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                logical.add(k.value)
            mesh.update(_str_consts(v))
    return logical, mesh


def _extract_mesh_axes(path: str):
    """Axis-name tuples from Mesh()/jax.make_mesh() calls."""
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    axes = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if name in ("Mesh", "make_mesh") and len(node.args) >= 2:
            axes.update(_str_consts(node.args[1]))
    return axes


def build_project_context(paths: list[str]) -> ProjectContext:
    """Locate the axis declarations under the scanned roots (or beside a
    scanned file) and build the context; fall back to the baked-in sets."""
    ctx = ProjectContext()
    candidates_rules, candidates_mesh = [], []
    for p in paths:
        p = os.path.abspath(p)
        root = os.path.dirname(p) if os.path.isfile(p) else p
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            if os.path.basename(dirpath) == "sharding" and "rules.py" in filenames:
                candidates_rules.append(os.path.join(dirpath, "rules.py"))
            if "mesh.py" in filenames and os.path.basename(dirpath) == "launch":
                candidates_mesh.append(os.path.join(dirpath, "mesh.py"))
    logical, mesh = set(), set()
    for path in candidates_rules:
        try:
            lg, ms = _extract_rules_table(path)
        except (OSError, SyntaxError):
            continue
        if lg:
            logical |= lg
            mesh |= ms
            ctx.rules_file = path
    for path in candidates_mesh:
        try:
            ms = _extract_mesh_axes(path)
        except (OSError, SyntaxError):
            continue
        if ms:
            mesh |= ms
            ctx.mesh_file = path
    if logical:
        ctx.logical_axes = frozenset(logical)
    if mesh:
        ctx.mesh_axes = frozenset(mesh)
    return ctx
