"""Baseline: grandfathered findings, checked in with a justification.

The baseline is a JSON file mapping finding fingerprints to a reason
string.  A finding whose fingerprint is in the baseline is reported as
``[baselined]`` and does not fail the run; a baseline entry that no longer
matches ANY finding is *stale* and fails the run (otherwise deleted
violations would leave dead entries behind, and re-introduced ones could
hide under them).

Fingerprints hash the rule + root-relative path + the stripped source line
(+ an occurrence index), so unrelated line-number drift does not invalidate
the baseline, but touching the flagged line itself does — deliberately:
grandfathering covers existing code, not edits to it.
"""

from __future__ import annotations

import dataclasses
import json

from repro.analysis.core import Finding


def load_baseline(path: str) -> dict[str, str]:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    entries = doc.get("entries", doc)  # tolerate a bare mapping
    if not isinstance(entries, dict):
        raise ValueError(f"{path}: baseline `entries` must be an object")
    return {str(k): str(v) for k, v in entries.items()}


def write_baseline(path: str, findings: list[Finding], reason: str) -> int:
    """Write every (non-baselined-marked) finding as a baseline entry."""
    entries = {f.fingerprint: f"{reason} [{f.rule} {f.path}:{f.line}]"
               for f in findings}
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=1, sort_keys=True)
        f.write("\n")
    return len(entries)


def apply_baseline(findings: list[Finding], baseline: dict[str, str]):
    """Split findings into (all, with baselined flags set) and the stale
    baseline fingerprints that matched nothing."""
    matched: set[str] = set()
    out = []
    for f in findings:
        fp = f.fingerprint
        if fp in baseline:
            matched.add(fp)
            out.append(dataclasses.replace(f, baselined=True))
        else:
            out.append(f)
    stale = sorted(set(baseline) - matched)
    return out, stale
