"""RETRACE — recompile / concretization hazards inside jitted functions.

The decode round is one jitted program per (shape, static-arg) key; the
hazards that silently re-trace it — or abort tracing outright — are:

  * host ``np.*`` calls inside a jitted body: numpy executes at trace time,
    constant-folding per trace (and raising on traced inputs), where
    ``jnp.*`` was meant;
  * Python scalar coercions (``int()``/``float()``/``bool()``/``.item()``/
    ``.tolist()``) of traced values: ``ConcretizationTypeError`` at best, a
    silent host sync at worst;
  * ``static_argnums``/``static_argnames`` pointing at a parameter whose
    default is a mutable literal: unhashable static args fail the jit cache
    key on every call.

A function counts as jitted when it is decorated with ``jax.jit`` (directly
or through ``functools.partial``), wrapped by a ``jax.jit(...)`` call in the
same file, or is a lambda passed inline to ``jax.jit``.
"""

from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Finding, ImportMap, Rule, register

_JIT_NAMES = frozenset({"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"})
_COERCIONS = frozenset({"int", "float", "bool", "complex"})
_SYNC_METHODS = frozenset({"item", "tolist"})


def _is_jit_ref(node: ast.AST, imports: ImportMap) -> bool:
    return imports.resolve(node) in _JIT_NAMES


def _jit_call_static_kwargs(call: ast.Call):
    for kw in call.keywords:
        if kw.arg in ("static_argnums", "static_argnames"):
            yield kw


def _partial_jit(dec: ast.AST, imports: ImportMap):
    """functools.partial(jax.jit, ...) decorator -> the partial Call."""
    if (isinstance(dec, ast.Call) and imports.resolve(dec.func)
            in ("functools.partial", "partial")
            and dec.args and _is_jit_ref(dec.args[0], imports)):
        return dec
    return None


class _JitCollector(ast.NodeVisitor):
    """Find every function node that ends up wrapped by jax.jit, paired with
    the jit call/decorator that wraps it (for static-arg inspection)."""

    def __init__(self, tree: ast.Module, imports: ImportMap):
        self.imports = imports
        self.jitted: list[tuple[ast.AST, ast.Call | None]] = []
        self._local_defs: dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._local_defs[node.name] = node
        self.visit(tree)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        for dec in node.decorator_list:
            if _is_jit_ref(dec, self.imports):
                self.jitted.append((node, None))
            elif isinstance(dec, ast.Call) and _is_jit_ref(dec.func, self.imports):
                self.jitted.append((node, dec))
            elif (p := _partial_jit(dec, self.imports)) is not None:
                self.jitted.append((node, p))
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call):
        if _is_jit_ref(node.func, self.imports) and node.args:
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                self.jitted.append((target, node))
            elif isinstance(target, ast.Name) and target.id in self._local_defs:
                self.jitted.append((self._local_defs[target.id], node))
        self.generic_visit(node)


@register
class RetraceRule(Rule):
    name = "RETRACE"
    description = ("np.* calls / Python scalar coercions / unhashable static "
                   "args inside jitted functions")

    def check(self, ctx: FileContext, project) -> list[Finding]:
        imports = ImportMap(ctx.tree)
        numpy_aliases = {local for local, canon in imports.names.items()
                         if canon == "numpy"}
        jitted = _JitCollector(ctx.tree, imports).jitted
        findings: list[Finding] = []
        seen_bodies: set[int] = set()
        for fn, jit_call in jitted:
            if jit_call is not None:
                findings.extend(self._check_static_args(ctx, fn, jit_call))
            if id(fn) in seen_bodies:  # e.g. jitted twice
                continue
            seen_bodies.add(id(fn))
            findings.extend(self._check_body(ctx, fn, numpy_aliases))
        return findings

    def _check_static_args(self, ctx, fn, jit_call) -> list[Finding]:
        out = []
        params = []
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            a = fn.args
            params = list(a.posonlyargs) + list(a.args)
            defaults = list(a.defaults)
            # align defaults to the trailing params
            pad = [None] * (len(params) - len(defaults))
            defaults = pad + defaults
        for kw in _jit_call_static_kwargs(jit_call):
            statics: set[int] = set()
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant):
                    if isinstance(c.value, int):
                        statics.add(c.value)
                    elif isinstance(c.value, str):
                        for i, p in enumerate(params):
                            if p.arg == c.value:
                                statics.add(i)
            for i in statics:
                if 0 <= i < len(params) and defaults[i] is not None and isinstance(
                        defaults[i], (ast.List, ast.Dict, ast.Set)):
                    out.append(ctx.finding(
                        self.name, kw,
                        f"static arg `{params[i].arg}` defaults to a mutable "
                        f"(unhashable) literal — every call misses the jit "
                        f"cache"))
        return out

    def _check_body(self, ctx, fn, numpy_aliases) -> list[Finding]:
        out = []
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                # nested defs inside a jitted body are traced too — keep them
                if isinstance(node, ast.Call):
                    root = node.func
                    while isinstance(root, ast.Attribute):
                        root = root.value
                    if (isinstance(root, ast.Name) and root.id in numpy_aliases
                            and isinstance(node.func, ast.Attribute)):
                        out.append(ctx.finding(
                            self.name, node,
                            "host numpy call inside a jitted function — "
                            "runs at trace time (use jnp.*)"))
                    elif (isinstance(node.func, ast.Name)
                          and node.func.id in _COERCIONS and node.args
                          and not isinstance(node.args[0], ast.Constant)):
                        out.append(ctx.finding(
                            self.name, node,
                            f"`{node.func.id}()` of a traced value inside a "
                            f"jitted function — concretization error or "
                            f"silent retrace"))
                    elif (isinstance(node.func, ast.Attribute)
                          and node.func.attr in _SYNC_METHODS
                          and not node.args):
                        out.append(ctx.finding(
                            self.name, node,
                            f"`.{node.func.attr}()` inside a jitted function "
                            f"forces concretization"))
        return out
