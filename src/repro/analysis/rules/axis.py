"""AXIS — axis-name literals cross-checked against the declared vocabulary.

A mesh-axis typo in a ``PartitionSpec`` or collective does not error: JAX
just replicates the dimension (or resolves against nothing), silently
erasing the sharding the spec claims.  Every string literal used as an axis
name is therefore checked against the axes the project actually declares
(extracted from ``sharding/rules.py`` + ``launch/mesh.py`` by
``repro.analysis.project``):

  * ``PartitionSpec(...)`` / ``P(...)`` entries — mesh axes;
  * collective ``axis_name`` arguments (``jax.lax.psum`` and friends,
    ``axis_index``, ``all_gather``) — mesh axes;
  * ``Mesh(devs, axes)`` / ``jax.make_mesh(shape, axes)`` tuples — mesh axes;
  * ``constrain(x, ...)`` / ``spec_for`` logical-axis names — logical axes.
"""

from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Finding, ImportMap, Rule, register

_PSPEC_NAMES = frozenset({
    "jax.sharding.PartitionSpec",
    "jax.interpreters.pxla.PartitionSpec",
})
_COLLECTIVES = frozenset({
    "jax.lax.psum", "jax.lax.pmean", "jax.lax.pmax", "jax.lax.pmin",
    "jax.lax.all_gather", "jax.lax.all_to_all", "jax.lax.ppermute",
    "jax.lax.pshuffle", "jax.lax.axis_index", "jax.lax.psum_scatter",
})
_CONSTRAIN_NAMES = frozenset({
    "repro.sharding.constrain", "repro.sharding.rules.constrain",
})
_MESH_CTORS = frozenset({
    "jax.sharding.Mesh", "jax.make_mesh", "jax.experimental.mesh_utils.Mesh",
})


def _axis_strs(node: ast.AST):
    """String constants in an axis argument (bare str or tuple/list of str)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value, node
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            yield from _axis_strs(elt)


@register
class AxisRule(Rule):
    name = "AXIS"
    description = ("PartitionSpec/collective/constrain axis names checked "
                   "against sharding/rules.py + launch/mesh.py declarations")

    def check(self, ctx: FileContext, project) -> list[Finding]:
        imports = ImportMap(ctx.tree)
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = imports.resolve(node.func) or ""
            if resolved in _PSPEC_NAMES or resolved.endswith(".PartitionSpec"):
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    findings.extend(self._check_axes(
                        ctx, arg, project.mesh_axes, "mesh"))
            elif resolved in _COLLECTIVES:
                cands = node.args[1:2] + [kw.value for kw in node.keywords
                                         if kw.arg in ("axis_name", "axis")]
                for arg in cands:
                    findings.extend(self._check_axes(
                        ctx, arg, project.mesh_axes, "mesh"))
            elif resolved in _MESH_CTORS or resolved.endswith(".Mesh"):
                if len(node.args) >= 2:
                    findings.extend(self._check_axes(
                        ctx, node.args[1], project.mesh_axes, "mesh"))
                for kw in node.keywords:
                    if kw.arg in ("axis_names", "axes"):
                        findings.extend(self._check_axes(
                            ctx, kw.value, project.mesh_axes, "mesh"))
            elif resolved in _CONSTRAIN_NAMES or (
                    isinstance(node.func, ast.Name)
                    and node.func.id == "constrain"):
                for arg in node.args[1:]:
                    findings.extend(self._check_axes(
                        ctx, arg, project.logical_axes, "logical"))
            elif resolved.endswith("shard_map"):
                # axis_name kwarg (specs' P(...) entries are caught above)
                for kw in node.keywords:
                    if kw.arg == "axis_name":
                        findings.extend(self._check_axes(
                            ctx, kw.value, project.mesh_axes, "mesh"))
        return findings

    def _check_axes(self, ctx, arg, declared, kind) -> list[Finding]:
        out = []
        for name, node in _axis_strs(arg):
            if name not in declared:
                close = _closest(name, declared)
                hint = f" (did you mean {close!r}?)" if close else ""
                out.append(ctx.finding(
                    self.name, node,
                    f"unknown {kind} axis {name!r} — declared {kind} axes: "
                    f"{sorted(declared)}{hint}"))
        return out


def _closest(name: str, declared) -> str | None:
    import difflib

    m = difflib.get_close_matches(name, list(declared), n=1, cutoff=0.6)
    return m[0] if m else None
