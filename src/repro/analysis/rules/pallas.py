"""PALLAS — BlockSpec/grid consistency for ``pl.pallas_call`` sites.

Pallas index-map bugs do not fail loudly: a wrong-arity index_map raises at
trace time in the best case, and a floor-division grid silently drops the
remainder rows of an unpadded input in the worst.  For every
``pallas_call`` whose grid is statically resolvable the rule checks:

  * each BlockSpec ``index_map`` lambda takes exactly ``len(grid)`` args;
  * an ``index_map`` returning a tuple literal returns one index per block
    dimension;
  * the kernel function takes ``len(in_specs) + n_outputs + n_scratch``
    refs;
  * the kernel body never writes an *input* ref (no matching output spec)
    unless the call declares ``input_output_aliases``;
  * grid components computed with ``//`` are guarded by a divisibility
    check (an assert/raise mentioning ``%``, or ``pl.cdiv``) in the same
    function — unpadded remainders must fail, not vanish.
"""

from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Finding, ImportMap, Rule, qualname, register


def _is_blockspec(node: ast.AST, imports: ImportMap) -> bool:
    if not isinstance(node, ast.Call):
        return False
    r = imports.resolve(node.func) or ""
    return r.endswith("BlockSpec")


def _lambda_arity(fn: ast.Lambda) -> int:
    a = fn.args
    return len(a.posonlyargs) + len(a.args)


def _enclosing_function(tree: ast.Module, call: ast.Call):
    """Innermost FunctionDef containing ``call`` (by position)."""
    best = None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if (node.lineno <= call.lineno
                    and call.lineno <= (node.end_lineno or node.lineno)):
                if best is None or node.lineno >= best.lineno:
                    best = node
    return best


def _resolve_grid_rank(call: ast.Call, fn) -> int | None:
    grid = next((kw.value for kw in call.keywords if kw.arg == "grid"), None)
    if grid is None:
        return None
    return _tuple_rank(grid, fn)


def _tuple_rank(expr: ast.AST, fn) -> int | None:
    if isinstance(expr, (ast.Tuple, ast.List)):
        return len(expr.elts)
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
        return 1
    if isinstance(expr, ast.Name) and fn is not None:
        # last assignment of that name before use, in the enclosing function
        rank = None
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == expr.id):
                rank = _tuple_rank(node.value, None)
        return rank
    return None


def _grid_floordivs(call: ast.Call, fn):
    """BinOp ``//`` nodes inside the grid expression (following one local
    name assignment)."""
    grid = next((kw.value for kw in call.keywords if kw.arg == "grid"), None)
    if grid is None:
        return
    exprs = [grid]
    if isinstance(grid, ast.Name) and fn is not None:
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == grid.id):
                exprs.append(node.value)
    for e in exprs:
        for node in ast.walk(e):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.FloorDiv):
                yield node


def _has_divisibility_guard(fn) -> bool:
    if fn is None:
        return False
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assert, ast.If)):
            for sub in ast.walk(node.test if isinstance(node, ast.If) else node):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    return True
        if isinstance(node, ast.Call) and (qualname(node.func) or "").endswith("cdiv"):
            return True
    return False


def _out_spec_list(call: ast.Call, imports):
    for kw in call.keywords:
        if kw.arg == "out_specs":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return list(kw.value.elts)
            return [kw.value]
    return []


@register
class PallasRule(Rule):
    name = "PALLAS"
    description = ("pallas_call BlockSpec/grid consistency: index_map arity, "
                   "block rank, kernel ref count, input-ref writes, "
                   "floor-div grids")

    def check(self, ctx: FileContext, project) -> list[Finding]:
        imports = ImportMap(ctx.tree)
        local_defs = {
            n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (imports.resolve(node.func) or "").endswith("pallas_call"):
                continue
            findings.extend(self._check_call(ctx, node, imports, local_defs))
        return findings

    def _check_call(self, ctx, call, imports, local_defs) -> list[Finding]:
        out: list[Finding] = []
        fn = _enclosing_function(ctx.tree, call)
        grid_rank = _resolve_grid_rank(call, fn)

        in_specs = []
        for kw in call.keywords:
            if kw.arg == "in_specs" and isinstance(kw.value, (ast.Tuple, ast.List)):
                in_specs = list(kw.value.elts)
        out_specs = _out_spec_list(call, imports)
        scratch = []
        for kw in call.keywords:
            if kw.arg == "scratch_shapes" and isinstance(kw.value, (ast.Tuple, ast.List)):
                scratch = list(kw.value.elts)
        has_alias = any(kw.arg == "input_output_aliases" for kw in call.keywords)

        # --- index_map arity / block rank per spec -------------------------
        for spec in in_specs + out_specs:
            if not _is_blockspec(spec, imports):
                continue
            shape = spec.args[0] if spec.args else None
            imap = spec.args[1] if len(spec.args) > 1 else next(
                (kw.value for kw in spec.keywords if kw.arg == "index_map"), None)
            if isinstance(imap, ast.Lambda):
                if grid_rank is not None and _lambda_arity(imap) != grid_rank:
                    out.append(ctx.finding(
                        self.name, imap,
                        f"index_map takes {_lambda_arity(imap)} arg(s) but the "
                        f"grid has rank {grid_rank}"))
                if (isinstance(imap.body, (ast.Tuple, ast.List))
                        and isinstance(shape, (ast.Tuple, ast.List))
                        and len(imap.body.elts) != len(shape.elts)):
                    out.append(ctx.finding(
                        self.name, imap,
                        f"index_map returns {len(imap.body.elts)} indices for "
                        f"a rank-{len(shape.elts)} block shape"))

        # --- kernel ref arity + input-ref writes ---------------------------
        kernel = call.args[0] if call.args else None
        kdef = None
        if isinstance(kernel, ast.Name):
            kdef = local_defs.get(kernel.id)
        if kdef is not None and in_specs:
            params = [a.arg for a in kdef.args.posonlyargs + kdef.args.args]
            expected = len(in_specs) + len(out_specs) + len(scratch)
            if out_specs and len(params) != expected:
                out.append(ctx.finding(
                    self.name, call,
                    f"kernel `{kdef.name}` takes {len(params)} refs but the "
                    f"call binds {len(in_specs)} input + {len(out_specs)} "
                    f"output + {len(scratch)} scratch specs"))
            if not has_alias:
                input_names = set(params[:len(in_specs)])
                for sub in ast.walk(kdef):
                    tgt = None
                    if isinstance(sub, ast.Assign):
                        tgt = sub.targets[0]
                    elif isinstance(sub, ast.AugAssign):
                        tgt = sub.target
                    if (isinstance(tgt, ast.Subscript)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id in input_names):
                        out.append(ctx.finding(
                            self.name, tgt,
                            f"kernel `{kdef.name}` writes input ref "
                            f"`{tgt.value.id}` which has no matching output "
                            f"spec (declare input_output_aliases or add an "
                            f"out_spec)"))

        # --- floor-division grids ------------------------------------------
        if not _has_divisibility_guard(fn):
            for fd in _grid_floordivs(call, fn):
                out.append(ctx.finding(
                    self.name, fd,
                    "floor-division grid silently drops the remainder of an "
                    "unpadded input — guard divisibility (assert/raise on "
                    "`%`) or use pl.cdiv"))
        return out
