"""CLOCK — raw wall-clock reads bypass the injected Clock abstraction.

Serving latency numbers (TTFT, tok/s, phase spans) are only comparable when
every timestamp flows through one clock: the runtimes' injectable
``WallClock``/``VirtualClock`` or ``repro.obs.clock.monotonic`` (the single
sanctioned raw read, itself carrying the one inline suppression).  A stray
``time.time()`` silently mixes non-monotonic wall time into monotonic
timelines and makes VirtualClock benchmarks lie.

Flags *references* (not just calls) to ``time.time`` / ``perf_counter`` /
``monotonic`` and friends, following import aliases — passing
``time.perf_counter`` as a default callback is exactly the bypass the rule
exists to catch.  ``time.sleep`` is allowed (it spends time, it does not
read it).
"""

from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Finding, ImportMap, Rule, register

BANNED = frozenset({
    "time.time", "time.time_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "time.clock_gettime", "time.clock_gettime_ns",
})


@register
class ClockRule(Rule):
    name = "CLOCK"
    description = ("raw wall-clock reads (time.time/perf_counter/...) outside "
                   "the Clock abstraction")

    def check(self, ctx: FileContext, project) -> list[Finding]:
        imports = ImportMap(ctx.tree)
        findings = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            # only the outermost attribute chain: time.perf_counter is one
            # reference, not also a reference to `time`
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                continue
            resolved = imports.resolve(node)
            if resolved in BANNED:
                findings.append(ctx.finding(
                    self.name, node,
                    f"raw wall-clock read `{resolved}` — inject a Clock or "
                    f"use repro.obs.clock.monotonic()"))
        # de-duplicate nested chains (Attribute visits its child Name too):
        # keep one finding per (line, col)
        seen, out = set(), []
        for f in findings:
            if (f.line, f.col) not in seen:
                seen.add((f.line, f.col))
                out.append(f)
        return out
