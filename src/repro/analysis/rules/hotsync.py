"""HOTSYNC — host syncs inside the paper's critical decode path.

SwiftSpec's round overlaps draft and target work via async dispatch; ONE
designated host sync per round (the verified-token transfer) is the
contract.  Any other ``jax.device_get`` / ``block_until_ready`` / implicit
array-``__bool__`` inside the round loop serializes the very overlap the
system exists to create — and on a fast engine a single stray sync is a
double-digit-percent regression that no test catches.

Scope: the hot round methods only —

  * ``SpecEngine.step`` / ``SpecEngine.generate`` (and the chain-engine
    equivalents),
  * every ``EngineStepper`` method (the per-round admit/absorb/retire path),
  * ``ServingRuntimeBase.run`` (the fleet round loop).

The intentional per-round sync point carries an inline
``# repro: disable=HOTSYNC`` with its justification; everything else is a
finding.
"""

from __future__ import annotations

import ast
import fnmatch

from repro.analysis.core import FileContext, Finding, ImportMap, Rule, register

# (class glob, method glob) pairs defining the hot path
HOT_SCOPES = (
    ("SpecEngine", "step"),
    ("SpecEngine", "generate"),
    ("ChainSpecEngine", "step"),
    ("ChainSpecEngine", "generate"),
    ("EngineSession", "*"),      # the bound round API: every phase method is hot
    ("ChainSession", "*"),
    ("EngineStepper", "*"),
    ("ServingRuntimeBase", "run"),
    ("*Runtime", "run"),
)

_SYNC_CALLS = frozenset({
    "jax.device_get", "jax.block_until_ready",
})


def _in_scope(cls_name: str, meth_name: str) -> bool:
    return any(fnmatch.fnmatch(cls_name, cg) and fnmatch.fnmatch(meth_name, mg)
               for cg, mg in HOT_SCOPES)


@register
class HotSyncRule(Rule):
    name = "HOTSYNC"
    description = ("device_get / block_until_ready / implicit array bool "
                   "inside the hot decode round")

    def check(self, ctx: FileContext, project) -> list[Finding]:
        imports = ImportMap(ctx.tree)
        jnp_aliases = {local for local, canon in imports.names.items()
                       if canon in ("jax.numpy", "jnp")}
        findings: list[Finding] = []
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if not _in_scope(cls.name, meth.name):
                    continue
                findings.extend(
                    self._check_method(ctx, imports, jnp_aliases, cls, meth))
        return findings

    def _check_method(self, ctx, imports, jnp_aliases, cls, meth) -> list[Finding]:
        out = []
        where = f"{cls.name}.{meth.name}"
        for node in ast.walk(meth):
            if isinstance(node, ast.Call):
                resolved = imports.resolve(node.func)
                if resolved in _SYNC_CALLS:
                    out.append(ctx.finding(
                        self.name, node,
                        f"`{resolved}` inside hot path {where} forces a host "
                        f"sync — keep it to the designated per-round sync "
                        f"point"))
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr == "block_until_ready"):
                    out.append(ctx.finding(
                        self.name, node,
                        f"`.block_until_ready()` inside hot path {where} "
                        f"stalls async dispatch"))
            elif isinstance(node, (ast.If, ast.While)):
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Call):
                        root = sub.func
                        while isinstance(root, ast.Attribute):
                            root = root.value
                        if isinstance(root, ast.Name) and root.id in jnp_aliases:
                            out.append(ctx.finding(
                                self.name, sub,
                                f"branching on a device array in hot path "
                                f"{where} triggers implicit __bool__ — a "
                                f"blocking transfer"))
        return out
