"""Rule modules; importing this package populates the registry."""

from repro.analysis.rules import axis, clock, hotsync, pallas, retrace  # noqa: F401
