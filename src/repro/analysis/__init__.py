"""repro.analysis — JAX/Pallas-aware static analysis for the decode path.

An AST-based lint pass (pure stdlib — it never imports jax, so CI can gate
on it without a device backend) with rules grounded in this repo's real
serving hazards:

  RETRACE  recompile/concretization hazards inside jitted functions
  AXIS     PartitionSpec/collective/constrain axis names vs. the axes
           declared in ``sharding/rules.py`` + ``launch/mesh.py``
  PALLAS   pallas_call BlockSpec/grid consistency
  CLOCK    raw wall-clock reads outside the Clock abstraction
  HOTSYNC  host syncs inside the hot decode round

Run ``python -m repro.analysis src/``; see docs/static-analysis.md for the
rule catalog, the ``# repro: disable=RULE`` suppression syntax and the
baseline workflow.
"""

from repro.analysis import rules as _rules  # noqa: F401 — populate REGISTRY
from repro.analysis.baseline import apply_baseline, load_baseline, write_baseline
from repro.analysis.cli import main
from repro.analysis.core import (
    REGISTRY,
    FileContext,
    Finding,
    Rule,
    analyze_file,
    analyze_paths,
    register,
)
from repro.analysis.project import ProjectContext, build_project_context
from repro.analysis.report import render_json, render_text, summarize

__all__ = [
    "REGISTRY",
    "FileContext",
    "Finding",
    "ProjectContext",
    "Rule",
    "analyze_file",
    "analyze_paths",
    "apply_baseline",
    "build_project_context",
    "load_baseline",
    "main",
    "register",
    "render_json",
    "render_text",
    "summarize",
    "write_baseline",
]
