"""``python -m repro.analysis`` — the CLI and exit-code semantics.

Exit codes:
  0  clean: no new findings, no stale baseline entries
  1  new findings and/or stale baseline entries
  2  usage/internal error (no files matched, unknown rule, bad baseline)

Typical invocations::

  python -m repro.analysis src/                       # gate the tree
  python -m repro.analysis src/ --format json         # machine report
  python -m repro.analysis src/ --write-baseline analysis-baseline.json \
      --reason "grandfathered at introduction"        # (re)baseline
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import rules as _rules  # noqa: F401 — populates REGISTRY
from repro.analysis.baseline import apply_baseline, load_baseline, write_baseline
from repro.analysis.core import REGISTRY, analyze_file, iter_py_files
from repro.analysis.project import build_project_context
from repro.analysis.report import render_json, render_text

DEFAULT_BASELINE = "analysis-baseline.json"


def build_arg_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas-aware static analysis guarding the hot "
                    "decode round (rules: %s)" % ", ".join(sorted(REGISTRY)))
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--format", choices=["text", "json"], default="text")
    ap.add_argument("--output", default=None,
                    help="write the report here instead of stdout")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON (default: ./{DEFAULT_BASELINE} "
                         f"when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", metavar="PATH", default=None,
                    help="write ALL current findings as the new baseline and "
                         "exit 0")
    ap.add_argument("--reason", default="grandfathered",
                    help="justification recorded with --write-baseline entries")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules to run")
    ap.add_argument("--list-rules", action="store_true")
    return ap


def main(argv=None) -> int:
    args = build_arg_parser().parse_args(argv)

    if args.list_rules:
        for name in sorted(REGISTRY):
            print(f"{name:10s} {REGISTRY[name].description}")
        return 0

    rules = None
    if args.rules:
        want = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in want if r not in REGISTRY]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)} "
                  f"(have: {', '.join(sorted(REGISTRY))})", file=sys.stderr)
            return 2
        rules = [REGISTRY[r] for r in want]

    files = list(iter_py_files(args.paths))
    if not files:
        print(f"no python files under: {' '.join(args.paths)}", file=sys.stderr)
        return 2

    project = build_project_context(args.paths)
    findings = []
    for abspath, root in files:
        findings.extend(analyze_file(abspath, root, project, rules))

    if args.write_baseline:
        n = write_baseline(args.write_baseline, findings, args.reason)
        print(f"wrote {n} baseline entr{'y' if n == 1 else 'ies'} -> "
              f"{args.write_baseline}")
        return 0

    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline and os.path.exists(DEFAULT_BASELINE):
        baseline_path = DEFAULT_BASELINE
    stale: list[str] = []
    if baseline_path and not args.no_baseline:
        try:
            baseline = load_baseline(baseline_path)
        except (OSError, ValueError) as e:
            print(f"cannot load baseline {baseline_path}: {e}", file=sys.stderr)
            return 2
        findings, stale = apply_baseline(findings, baseline)

    render = render_json if args.format == "json" else render_text
    text = render(findings, stale, len(files))
    if args.output:
        with open(args.output, "w", encoding="utf-8") as f:
            f.write(text + "\n")
    else:
        print(text)

    new = sum(1 for f in findings if not f.baselined)
    return 1 if (new or stale) else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
