"""Trace-time feature flags (kernel selection, MoE impl, remat policy).

Flags are read while tracing/jitting, so changing them re-specializes the
compiled program.  They drive the §Perf hillclimb knobs and the ablation
benchmark configurations (paper Fig. 8: kernels on/off x parallel on/off).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading


@dataclasses.dataclass
class Flags:
    use_pallas_attention: bool = False  # tree/decode attention Pallas kernels
    use_pallas_swiglu: bool = False  # fused SwiGLU kernel
    use_int4_kernel: bool = False  # AWQ dequant-GEMM kernel
    use_pallas_kv_moves: bool = False  # fused O(moved-rows) KV reorg kernels
    pallas_interpret: bool = True  # CPU container: interpret mode
    moe_impl: str = "tp"  # "tp" (TP-in-expert) | "ep" (expert-parallel a2a)
    remat: str = "none"  # "none" | "full"
    attn_chunk: int = 512  # q-chunk for full attention
    scan_layers: bool = True  # scan over layer stack (compile-time win)
    collective_matmul: bool = False  # ring collective-matmul decomposition
    seq_shard_acts: bool = False  # sequence parallelism: residuals + KV sharded
    #   over "model" between blocks (train/prefill memory fit at scale)
    attn_heads_tp: bool = False  # under seq_shard_acts: compute attention
    #   head-parallel (Megatron-SP): AG(k,v) + head-sharded scores instead of
    #   seq-sharded scores with per-chunk psum (§Perf collective hillclimb)


_CTX = threading.local()


def get_flags() -> Flags:
    f = getattr(_CTX, "flags", None)
    if f is None:
        f = Flags()
        _CTX.flags = f
    return f


@contextlib.contextmanager
def override_flags(**kw):
    prev = get_flags()
    cur = dataclasses.replace(prev, **kw)
    _CTX.flags = cur
    try:
        yield cur
    finally:
        _CTX.flags = prev
