"""Groupwise int4 weight quantization (AWQ-style, paper §5.1).

The paper serves every transformer-layer weight as 4-bit AWQ with group size
128.  We reproduce the serving-side artifact exactly — per-group scale + zero
point, nibble-packed storage, dequant-GEMM consumption — and replace AWQ's
activation-aware scale *search* with min/max calibration (DESIGN.md §8: the
search changes values, not structure, and needs calibration data we don't
ship offline).

Packing: values in [0, 15]; byte b of column n holds k=2b in the low nibble
and k=2b+1 in the high nibble — matching kernels/int4_matmul.py's unpack.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedLinear(NamedTuple):
    qweight: jax.Array  # int8 [K//2, N] packed nibbles
    scales: jax.Array  # f32 [K//g, N]
    zeros: jax.Array  # f32 [K//g, N]
    group_size: int


def quantize_groupwise(w, group_size: int = 128) -> QuantizedLinear:
    """w: [K, N] float.  Min/max asymmetric 4-bit per (group, column)."""
    K, N = w.shape
    assert K % group_size == 0, (K, group_size)
    wg = w.astype(jnp.float32).reshape(K // group_size, group_size, N)
    wmin = jnp.min(wg, axis=1)  # [G, N]
    wmax = jnp.max(wg, axis=1)
    scales = jnp.maximum((wmax - wmin) / 15.0, 1e-8)
    zeros = -wmin / scales  # q = w/s + z  in [0, 15]
    q = jnp.clip(jnp.round(wg / scales[:, None, :] + zeros[:, None, :]), 0, 15)
    q = q.reshape(K, N).astype(jnp.int8)
    return QuantizedLinear(pack_int4(q), scales, zeros, group_size)


def pack_int4(q) -> jax.Array:
    """int8 [K, N] values 0..15 -> packed int8 [K//2, N]."""
    K, N = q.shape
    assert K % 2 == 0
    pairs = q.reshape(K // 2, 2, N).astype(jnp.uint8)
    packed = pairs[:, 0, :] | (pairs[:, 1, :] << 4)
    return packed.astype(jnp.int8)


def unpack_int4(packed) -> jax.Array:
    """packed int8 [K//2, N] -> int8 [K, N] values 0..15."""
    p = packed.astype(jnp.uint8)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    K2, N = p.shape
    return jnp.stack([lo, hi], axis=1).reshape(K2 * 2, N).astype(jnp.int8)


def dequantize(q: QuantizedLinear) -> jax.Array:
    """Reference dense reconstruction (the oracle for the Pallas kernel)."""
    w = unpack_int4(q.qweight).astype(jnp.float32)
    s = jnp.repeat(q.scales, q.group_size, axis=0)
    z = jnp.repeat(q.zeros, q.group_size, axis=0)
    return (w - z) * s
