from repro.quant.awq import dequantize, pack_int4, quantize_groupwise, unpack_int4

__all__ = ["dequantize", "pack_int4", "quantize_groupwise", "unpack_int4"]
