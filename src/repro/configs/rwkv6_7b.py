"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free.
[arXiv:2404.05892; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    n_layers=32,
    d_model=4096,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv6",),
    attn_kind="none",
    ssm_head_dim=64,  # wkv head size
    family="ssm",
    source="arXiv:2404.05892; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=128,
        vocab_size=256,
        block_pattern=("rwkv6",),
        attn_kind="none",
        ssm_head_dim=16,
        family="ssm",
    )
