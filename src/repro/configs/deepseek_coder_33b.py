"""deepseek-coder-33b [dense] — llama-arch. Paper target model (§5, Table 4).
[arXiv:2401.14196; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=1e5,
    family="dense",
    source="arXiv:2401.14196; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        family="dense",
    )
