"""llama3.2-3b — the paper's draft model for Llama3-70B."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    family="dense",
    source="llama3.2; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-3b-smoke",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        family="dense",
    )
