"""qwen2.5-14b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        family="dense",
    )
