"""Config system: model configs, input-shape cells, and the registry.

Each assigned architecture lives in ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (full published shape) and ``smoke_config()`` (reduced same-family
shape for CPU tests).  ``registry.get(name)`` resolves either.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int  # query heads; 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # block pattern: layer kind cycled over layers. kinds:
    #   dense  = attn + swiglu-mlp
    #   moe    = attn + mixture-of-experts
    #   mamba2 = mamba2 ssd block
    #   rwkv6  = rwkv time-mix + channel-mix
    #   cross  = cross-attention (to stub encoder states) + swiglu-mlp
    block_pattern: tuple = ("dense",)

    # attention
    attn_kind: str = "gqa"  # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0  # 0 = full attention

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0  # per-expert ff (deepseek fine-grained); 0 -> d_ff

    # MLA (minicpm3 / deepseek-v2 style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_groups: int = 1

    # hybrid / modality wiring
    first_k_dense: int = 0  # deepseek-moe: dense prologue layers
    shared_attn_every: int = 0  # zamba2: shared attn block every k layers
    cross_attn_every: int = 0  # vlm: cross block every k layers (pattern helper)
    n_enc_tokens: int = 0  # stub encoder sequence length (vlm/audio cond)
    embed_inputs: bool = True  # False: train/prefill consume embeddings (stub frontend)

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "float32"  # compute dtype
    param_dtype: str = "float32"

    # annotations
    family: str = "dense"  # dense|moe|ssm|hybrid|audio|vlm
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived -------------------------------------------------------
    @property
    def layer_kinds(self) -> tuple:
        kinds = ["dense"] * self.first_k_dense
        for i in range(self.n_layers - self.first_k_dense):
            kinds.append(self.block_pattern[i % len(self.block_pattern)])
        return tuple(kinds)

    @property
    def attention_free(self) -> bool:
        return all(k in ("mamba2", "rwkv6") for k in self.layer_kinds) and not self.shared_attn_every

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state does not grow quadratically (SSM / hybrid)."""
        return any(k in ("mamba2", "rwkv6") for k in self.layer_kinds)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d  # lm head
        for kind in self.layer_kinds:
            total += self._block_params(kind)
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        d, V = self.d_model, self.vocab_size
        total = V * d + (0 if self.tie_embeddings else V * d) + d
        for kind in self.layer_kinds:
            total += self._block_params(kind, active=True)
        return total

    def _block_params(self, kind: str, active: bool = False) -> int:
        d, ff = self.d_model, self.d_ff
        hd = self.head_dim
        if kind == "dense":
            return self._attn_params() + 3 * d * ff + 2 * d
        if kind == "cross":
            return self._attn_params() + 3 * d * ff + 2 * d
        if kind == "moe":
            eff = self.moe_d_ff or ff
            n_routed = self.moe_top_k if active else self.n_experts
            gate = d * self.n_experts
            shared = self.n_shared_experts * 3 * d * eff
            return self._attn_params() + gate + shared + n_routed * 3 * d * eff + 2 * d
        if kind == "mamba2":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            conv_dim = d_in + 2 * self.ssm_groups * self.ssm_state
            return (
                d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nheads)
                + conv_dim * self.ssm_conv
                + d_in * d
                + 2 * nheads
                + d
            )
        if kind == "rwkv6":
            # time-mix: r,k,v,g,o projections + decay/bonus; channel-mix: 2 mats
            return 5 * d * d + 2 * d + d * ff + ff * d + 2 * d
        raise ValueError(kind)

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.attn_kind == "mla":
            qk = self.nope_head_dim + self.rope_head_dim
            return (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * qk
                + d * (self.kv_lora_rank + self.rope_head_dim)
                + self.kv_lora_rank * self.n_heads * (self.nope_head_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        if self.attn_kind == "none" or self.n_heads == 0:
            return 0
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d


# -----------------------------------------------------------------------------
# Shape cells (assignment: 4 shapes per LM arch).
# -----------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """long_500k only runs for sub-quadratic archs (assignment rule)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k dense-KV decode is the quadratic regime the shape excludes (DESIGN.md §6)"
    return True, ""


# -----------------------------------------------------------------------------
# Arbitrary-TP padding (paper §4 "Enabling arbitrary tensor parallelism").
# -----------------------------------------------------------------------------


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m if m > 1 else x


def resolve_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Zero-pad head counts / ff dims so every matmul splits across ``tp``.

    Mirrors the paper's padding scheme: padded attention heads and ff columns
    are zero-initialized so outputs are exactly equivalent to the unpadded
    model (tests assert this).

    GQA constraint: the padded query-head count must stay a multiple of the
    KV-head count (the grouping reshape).  Two legal schemes — widen each KV
    group (heads -> lcm(tp, kv)) or widen the KV heads at fixed group size —
    and the cheaper one (fewer query heads; ties avoid touching the KV cache)
    is chosen per architecture.
    """
    if tp <= 1:
        return cfg
    changes = {}
    if cfg.n_heads and cfg.n_heads % tp:
        if cfg.attn_kind == "mla" or cfg.n_kv_heads in (0, cfg.n_heads):
            # no grouping reshape (MLA / MHA): pad both together
            hq = _pad_to(cfg.n_heads, tp)
            changes["n_heads"] = hq
            if cfg.n_kv_heads == cfg.n_heads:
                changes["n_kv_heads"] = hq
        else:
            g = cfg.n_heads // cfg.n_kv_heads
            cand_a = _pad_to(cfg.n_heads, math.lcm(tp, cfg.n_kv_heads))
            hkv_b = _pad_to(cfg.n_kv_heads, tp)
            cand_b = g * hkv_b
            if cand_b < cand_a:
                changes["n_heads"], changes["n_kv_heads"] = cand_b, hkv_b
            else:
                changes["n_heads"] = cand_a
    if cfg.d_ff % tp:
        changes["d_ff"] = _pad_to(cfg.d_ff, tp)
    if cfg.moe_d_ff and cfg.moe_d_ff % tp:
        changes["moe_d_ff"] = _pad_to(cfg.moe_d_ff, tp)
    if not changes:
        return cfg
    if "n_heads" in changes and cfg.head_dim:
        changes["head_dim"] = cfg.head_dim  # keep head_dim; widen head count only
    return replace(cfg, **changes)


# -----------------------------------------------------------------------------
# Registry.
# -----------------------------------------------------------------------------

ASSIGNED = [
    "mixtral-8x22b",
    "deepseek-moe-16b",
    "qwen2.5-14b",
    "granite-20b",
    "deepseek-coder-33b",
    "minicpm3-4b",
    "musicgen-large",
    "zamba2-2.7b",
    "llama-3.2-vision-90b",
    "rwkv6-7b",
]

PAPER_OWN = ["llama3-70b", "llama3-8b", "llama3-3b", "llama3-1b", "deepseek-coder-1.3b"]


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    import importlib

    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.CONFIG


def all_arch_names():
    return list(ASSIGNED)
