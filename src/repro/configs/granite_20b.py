"""granite-20b [dense] — llama-arch, code, MQA (kv=1). [arXiv:2405.04324; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    family="dense",
    source="arXiv:2405.04324; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        family="dense",
    )
