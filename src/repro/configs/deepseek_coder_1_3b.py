"""deepseek-coder-1.3b — the paper's draft model for deepseek-coder-33b."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-1.3b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5504,
    vocab_size=32256,
    rope_theta=1e5,
    family="dense",
    source="arXiv:2401.14196; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-1.3b-smoke",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab_size=256,
        family="dense",
    )
