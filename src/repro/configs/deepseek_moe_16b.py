"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # per-expert fine-grained ff
    vocab_size=102400,
    block_pattern=("moe",),
    first_k_dense=1,  # layer 0 dense, layers 1..27 moe (deepseek-moe)
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    family="moe",
    source="arXiv:2401.06066; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=256,
        block_pattern=("moe",),
        first_k_dense=1,
        n_experts=8,
        n_shared_experts=2,
        moe_top_k=3,
        moe_d_ff=96,
        capacity_factor=8.0,  # drop-free for exact-match smoke tests
        family="moe",
    )
