"""llama3.2-1b — paper Table 1 draft-scaling subject."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-1b",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    family="dense",
    source="llama3.2; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-1b-smoke",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=256,
        family="dense",
    )
