"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

54 Mamba2 layers with one weight-shared attention block invoked every 6
layers (the public model interleaves two shared blocks; simplification noted
in DESIGN.md §8).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("mamba2",),
    shared_attn_every=6,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    head_dim=80,
    family="hybrid",
    source="arXiv:2411.15242; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        block_pattern=("mamba2",),
        shared_attn_every=2,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        head_dim=16,
        family="hybrid",
    )
