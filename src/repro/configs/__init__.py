from repro.configs.base import (
    ASSIGNED,
    PAPER_OWN,
    SHAPES,
    ModelConfig,
    ShapeCell,
    all_arch_names,
    cell_applicable,
    get_config,
    resolve_for_tp,
)

__all__ = [
    "ASSIGNED",
    "PAPER_OWN",
    "SHAPES",
    "ModelConfig",
    "ShapeCell",
    "all_arch_names",
    "cell_applicable",
    "get_config",
    "resolve_for_tp",
]
