"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    block_pattern=("moe",),
    n_experts=8,
    moe_top_k=2,
    sliding_window=4096,
    rope_theta=1e6,
    family="moe",
    source="arXiv:2401.04088; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        block_pattern=("moe",),
        n_experts=4,
        moe_top_k=2,
        sliding_window=32,
        capacity_factor=8.0,  # drop-free for exact-match smoke tests
        family="moe",
    )
