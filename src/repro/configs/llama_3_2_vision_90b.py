"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Backbone only: the ViT tower is a STUB — ``input_specs()`` provides
precomputed vision-patch embeddings (n_enc_tokens x d_model).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("dense", "dense", "dense", "dense", "cross"),
    cross_attn_every=5,
    n_enc_tokens=1024,
    rope_theta=5e5,
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-smoke",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        block_pattern=("dense", "dense", "dense", "dense", "cross"),
        cross_attn_every=5,
        n_enc_tokens=16,
        family="vlm",
    )
