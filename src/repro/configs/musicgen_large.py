"""musicgen-large [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings for train/prefill; decode consumes EnCodec token
ids through the decoder's own embedding table (vocab 2048).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    embed_inputs=False,  # stub frontend feeds frame embeddings
    family="audio",
    source="arXiv:2306.05284; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        embed_inputs=False,
        family="audio",
    )
