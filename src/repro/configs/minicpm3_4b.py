"""minicpm3-4b [dense] — MLA attention. [hf:openbmb/MiniCPM3-4B; hf]

MLA inner dims follow the public MiniCPM3 config (q_lora 768, kv_lora 256,
rope 32, nope 64, v 64); the assignment line pins only the outer shape.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    rope_head_dim=32,
    nope_head_dim=64,
    v_head_dim=64,
    head_dim=96,  # nope + rope
    family="dense",
    source="hf:openbmb/MiniCPM3-4B; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        attn_kind="mla",
        q_lora_rank=32,
        kv_lora_rank=16,
        rope_head_dim=8,
        nope_head_dim=16,
        v_head_dim=16,
        head_dim=24,
        family="dense",
    )
