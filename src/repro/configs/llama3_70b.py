"""llama3-70b — the paper's headline target model (348 tok/s highlight)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-70b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    family="dense",
    source="llama3 tech report; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-70b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        family="dense",
    )
