"""llama3-8b — paper Table 1 TP-scaling subject / R1-distill draft analogue."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    family="dense",
    source="llama3 tech report; hf",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        family="dense",
    )
