"""repro.obs — tracing + metrics for the speculative serving stack.

SwiftSpec's argument is a latency decomposition; this package is the
instrument that measures it end to end:

``trace``
    ``Tracer`` — ring-buffered phase spans with monotonic timestamps,
    Chrome/Perfetto ``trace.json`` + JSONL export, and a zero-allocation
    disabled path (``NULL_TRACER``/``NOOP_SPAN``).  Woven through
    ``SpecEngine.step`` (verify dispatch / draft expand / emitted sync /
    reroot+grow), ``EngineStepper`` (admit prefill, absorb, retire) and the
    serving runtimes (routing, queue pop, per-replica round spans).
``metrics``
    ``MetricsRegistry`` — labeled counters / gauges / fixed-bucket
    histograms / bounded sample series, with a structured ``snapshot()``
    and a Prometheus text dump.  The runtimes populate per-replica round
    counters, the accepted-depth histogram, TTFT, queue-depth-over-time
    and KV-truncation counts.
``report``
    ``phase_breakdown`` / ``breakdown_report`` — per-round draft vs.
    verify vs. absorb decomposition (the paper's imbalance, measured) with
    a span-coverage completeness check.

Quick start::

    from repro.obs import MetricsRegistry, Tracer, breakdown_report, phase_breakdown

    tracer, metrics = Tracer(), MetricsRegistry()
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=4,
                                   tracer=tracer, metrics=metrics)
    ...
    tracer.write("trace.json")            # open in ui.perfetto.dev
    metrics.write("metrics.json", extra={"phase_breakdown": phase_breakdown(tracer)})
    print(breakdown_report(phase_breakdown(tracer)))
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Series,
    merge_histograms,
)
from repro.obs.report import breakdown_report, phase_breakdown
from repro.obs.trace import NOOP_SPAN, NULL_TRACER, Span, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NOOP_SPAN",
    "NULL_TRACER",
    "Series",
    "Span",
    "Tracer",
    "breakdown_report",
    "merge_histograms",
    "phase_breakdown",
]
