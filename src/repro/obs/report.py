"""Round-time decomposition — the paper's draft/verify imbalance, measured.

Folds a ``Tracer``'s spans into a per-round latency breakdown: how much of
each serving round went to draft-tree work (expansion + KV reconciliation
after re-root), target verification (dispatch + the verified-token device
sync), and host-side absorption.  This is the baseline evidence the async
disaggregation work (ROADMAP #1) needs — the whole point of running draft
and target concurrently is to hide the smaller of the draft/verify fractions
reported here.

Span taxonomy (docs/observability.md):
  round         one global serving round on one replica track
  ├─ verify_dispatch   enqueue target verification (async dispatch)
  ├─ draft_expand      the d concurrent tree expansions (parallel mode)
  ├─ sync_emitted      host sync on the verified-token transfer
  ├─ reroot_grow       tree re-root + KV fill + regrow + next plan
  └─ absorb            host-side token absorption / retire / stream
"""

from __future__ import annotations

# top-level phases inside one round span (nested spans, e.g. ``retire``
# inside ``absorb``, are excluded so coverage never double-counts)
ROUND_PHASES = ("verify_dispatch", "draft_expand", "sync_emitted",
                "reroot_grow", "absorb")
PHASE_GROUPS = {
    "draft": ("draft_expand", "reroot_grow"),
    "verify": ("verify_dispatch", "sync_emitted"),
    "absorb": ("absorb",),
}


def phase_breakdown(tracer) -> dict:
    """Decompose every ``round`` span into its phase children.

    Returns per-phase totals/fractions, the draft/verify/absorb grouping,
    and span coverage (fraction of round wall time accounted for by phase
    spans — the instrument-completeness check; ≥0.95 means the trace
    explains where each round's milliseconds went)."""
    spans = tracer.spans()
    rounds = sorted((s for s in spans if s.name == "round"),
                    key=lambda s: (s.track, s.t0))
    by_track: dict[str, list] = {}
    for s in spans:
        if s.name in ROUND_PHASES:
            by_track.setdefault(s.track, []).append(s)
    for v in by_track.values():
        v.sort(key=lambda s: s.t0)

    phase_s = dict.fromkeys(ROUND_PHASES, 0.0)
    coverages: list[float] = []
    round_total = 0.0
    cursor = dict.fromkeys(by_track, 0)  # per-track scan position
    for r in rounds:
        round_total += r.dur
        covered = 0.0
        kids = by_track.get(r.track, ())
        i = cursor.get(r.track, 0)
        # skip children that ended before this round began (earlier rounds)
        while i < len(kids) and kids[i].t0 < r.t0:
            i += 1
        cursor[r.track] = i
        while i < len(kids) and kids[i].t0 < r.t1:
            if kids[i].t1 <= r.t1:
                phase_s[kids[i].name] += kids[i].dur
                covered += kids[i].dur
            i += 1
        if r.dur > 0:
            coverages.append(covered / r.dur)

    # zero rounds (empty trace) must read as "unknown", not "instantaneous":
    # a 0.0 mean_round_s or coverage from a dead tracer would sail straight
    # through dashboards and the CI coverage gate, so every ratio whose
    # denominator is empty is nan-marked instead
    nan = float("nan")
    out = {
        "n_rounds": len(rounds),
        "round_total_s": round_total,
        "mean_round_s": round_total / len(rounds) if rounds else nan,
        "phase_s": phase_s,
        "phase_frac": {
            k: (v / round_total if round_total else nan) for k, v in phase_s.items()
        },
        "coverage_mean": sum(coverages) / len(coverages) if coverages else nan,
        "coverage_min": min(coverages) if coverages else nan,
    }
    for group, members in PHASE_GROUPS.items():
        tot = sum(phase_s[m] for m in members)
        out[f"{group}_s"] = tot
        out[f"{group}_frac"] = tot / round_total if round_total else nan
    return out


def breakdown_report(bd: dict) -> str:
    """Human-readable view of ``phase_breakdown`` output."""
    if not bd["n_rounds"]:
        return "phase breakdown: no rounds traced"
    lines = [
        f"phase breakdown over {bd['n_rounds']} rounds "
        f"(mean round {bd['mean_round_s'] * 1e3:.2f} ms, "
        f"span coverage mean={bd['coverage_mean']:.1%} min={bd['coverage_min']:.1%})"
    ]
    for name in ROUND_PHASES:
        lines.append(f"  {name:15s} {bd['phase_s'][name] * 1e3:9.2f} ms "
                     f"{bd['phase_frac'][name]:6.1%}")
    lines.append(
        f"  => draft {bd['draft_frac']:.1%} / verify {bd['verify_frac']:.1%} "
        f"/ absorb {bd['absorb_frac']:.1%} of round wall time"
    )
    return "\n".join(lines)
