"""Round-time decomposition — the paper's draft/verify imbalance, measured.

Folds a ``Tracer``'s spans into a per-round latency breakdown: how much of
each serving round went to draft-tree work (expansion + KV reconciliation
after re-root), target verification (dispatch + the verified-token device
sync), and host-side absorption.  With async disaggregation on
(``SpecConfig.async_rounds``) the breakdown additionally measures the
pipeline's whole point: the wall time where ``draft_lookahead`` ran *inside*
the open verify window (``overlap_draft_verify_s``), and the draft time that
stayed serialized on the critical path (``draft_serialized_s`` /
``draft_serialized_frac`` — the number async mode exists to shrink).

Span taxonomy (docs/observability.md):
  round         one global serving round on one replica track
  ├─ verify_dispatch   target verification window; lockstep: the enqueue
  │                    only (async dispatch), async rounds: held open from
  │                    dispatch until the verified tokens land
  ├─ draft_expand      the d concurrent tree expansions (lockstep parallel mode)
  ├─ draft_lookahead   async: next round's tree drafted on the predicted-
  │                    accept path while verify is still in flight
  ├─ sync_emitted      host sync on the verified-token transfer
  ├─ reroot_grow       tree re-root + KV fill + regrow + next plan (lockstep)
  ├─ reconcile         async: rollback + re-root after a rejected lookahead seed
  └─ absorb            host-side token absorption / retire / stream

``kv_move`` is a nested *detail* span (inside verify_dispatch, reroot_grow,
draft_lookahead, or reconcile): the KV-reorganization dispatch that the
fused row-move kernels attack (docs/kernels.md).  It is reported on its own
``kv_move_s``/``kv_move_frac`` keys but deliberately kept out of
ROUND_PHASES so the coverage/overlap unions never double-count its parent.

Because async phases genuinely overlap (that is the feature), coverage and
the overlap metrics are computed on interval *unions* per round, never by
summing durations — a nested span can't push coverage past 1.0 or count the
same wall-clock millisecond twice.
"""

from __future__ import annotations

# top-level phases inside one round span (nested spans, e.g. ``retire``
# inside ``absorb``, are excluded so coverage never double-counts)
ROUND_PHASES = ("verify_dispatch", "draft_expand", "draft_lookahead",
                "sync_emitted", "reconcile", "reroot_grow", "absorb")
PHASE_GROUPS = {
    "draft": ("draft_expand", "draft_lookahead", "reconcile", "reroot_grow"),
    "verify": ("verify_dispatch", "sync_emitted"),
    "absorb": ("absorb",),
}
# nested detail spans: measured and reported on their own keys but NEVER
# part of the coverage/overlap unions — they live inside a ROUND_PHASES
# parent (kv_move = the cache-reorganization dispatch inside verify_dispatch
# / reroot_grow / draft_lookahead / reconcile; see docs/kernels.md)
DETAIL_PHASES = ("kv_move",)


def _merge(intervals):
    """Coalesce [t0, t1) intervals into a sorted disjoint union."""
    out: list[list[float]] = []
    for t0, t1 in sorted(intervals):
        if out and t0 <= out[-1][1]:
            out[-1][1] = max(out[-1][1], t1)
        else:
            out.append([t0, t1])
    return out


def _length(intervals) -> float:
    return sum(t1 - t0 for t0, t1 in intervals)


def _intersect(a, b):
    """Intersection of two sorted disjoint interval unions."""
    out, i, j = [], 0, 0
    while i < len(a) and j < len(b):
        lo, hi = max(a[i][0], b[j][0]), min(a[i][1], b[j][1])
        if lo < hi:
            out.append([lo, hi])
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return out


def phase_breakdown(tracer) -> dict:
    """Decompose every ``round`` span into its phase children.

    Returns per-phase totals/fractions, the draft/verify/absorb grouping,
    span coverage (union of phase intervals over round wall time — the
    instrument-completeness check; ≥0.95 means the trace explains where each
    round's milliseconds went), and the async-pipeline evidence:
    ``overlap_draft_verify_s`` (draft wall time inside the verify window)
    and ``draft_serialized_s``/``draft_serialized_frac`` (draft wall time
    still on the critical path)."""
    spans = tracer.spans()
    rounds = sorted((s for s in spans if s.name == "round"),
                    key=lambda s: (s.track, s.t0))
    by_track: dict[str, list] = {}
    detail_by_track: dict[str, list] = {}
    for s in spans:
        if s.name in ROUND_PHASES:
            by_track.setdefault(s.track, []).append(s)
        elif s.name in DETAIL_PHASES:
            detail_by_track.setdefault(s.track, []).append(s)
    for v in by_track.values():
        v.sort(key=lambda s: s.t0)
    for v in detail_by_track.values():
        v.sort(key=lambda s: s.t0)

    phase_s = dict.fromkeys(ROUND_PHASES, 0.0)
    detail_s = dict.fromkeys(DETAIL_PHASES, 0.0)
    coverages: list[float] = []
    round_total = 0.0
    overlap_s = 0.0
    draft_union_s = 0.0
    cursor = dict.fromkeys(by_track, 0)  # per-track scan position
    dcursor = dict.fromkeys(detail_by_track, 0)
    for r in rounds:
        round_total += r.dur
        kids_here: list = []
        kids = by_track.get(r.track, ())
        i = cursor.get(r.track, 0)
        # skip children that ended before this round began (earlier rounds)
        while i < len(kids) and kids[i].t0 < r.t0:
            i += 1
        cursor[r.track] = i
        while i < len(kids) and kids[i].t0 < r.t1:
            if kids[i].t1 <= r.t1:
                phase_s[kids[i].name] += kids[i].dur
                kids_here.append(kids[i])
            i += 1
        dkids = detail_by_track.get(r.track, ())
        j = dcursor.get(r.track, 0)
        while j < len(dkids) and dkids[j].t0 < r.t0:
            j += 1
        dcursor[r.track] = j
        while j < len(dkids) and dkids[j].t0 < r.t1:
            if dkids[j].t1 <= r.t1:
                detail_s[dkids[j].name] += dkids[j].dur
            j += 1
        covered = _length(_merge([(k.t0, k.t1) for k in kids_here]))
        if r.dur > 0:
            coverages.append(covered / r.dur)
        draft_win = _merge([(k.t0, k.t1) for k in kids_here
                            if k.name in PHASE_GROUPS["draft"]])
        verify_win = _merge([(k.t0, k.t1) for k in kids_here
                             if k.name in PHASE_GROUPS["verify"]])
        overlap_s += _length(_intersect(draft_win, verify_win))
        draft_union_s += _length(draft_win)

    # zero rounds (empty trace) must read as "unknown", not "instantaneous":
    # a 0.0 mean_round_s or coverage from a dead tracer would sail straight
    # through dashboards and the CI coverage gate, so every ratio whose
    # denominator is empty is nan-marked instead
    nan = float("nan")
    out = {
        "n_rounds": len(rounds),
        "round_total_s": round_total,
        "mean_round_s": round_total / len(rounds) if rounds else nan,
        "phase_s": phase_s,
        "phase_frac": {
            k: (v / round_total if round_total else nan) for k, v in phase_s.items()
        },
        "coverage_mean": sum(coverages) / len(coverages) if coverages else nan,
        "coverage_min": min(coverages) if coverages else nan,
        # async-pipeline evidence: draft wall time hidden under the verify
        # window vs. still serialized on the critical path (union-based, so
        # lockstep traces report overlap == 0.0 exactly)
        "overlap_draft_verify_s": overlap_s,
        "draft_serialized_s": draft_union_s - overlap_s,
        "draft_serialized_frac": (
            (draft_union_s - overlap_s) / round_total if round_total else nan
        ),
        # nested detail: wall time of the KV-reorganization dispatch (the
        # fused kv_move_rows path) across ALL round phases it nests inside
        "kv_move_s": detail_s["kv_move"],
        "kv_move_frac": detail_s["kv_move"] / round_total if round_total else nan,
    }
    for group, members in PHASE_GROUPS.items():
        tot = sum(phase_s[m] for m in members)
        out[f"{group}_s"] = tot
        out[f"{group}_frac"] = tot / round_total if round_total else nan
    return out


def breakdown_report(bd: dict) -> str:
    """Human-readable view of ``phase_breakdown`` output."""
    if not bd["n_rounds"]:
        return "phase breakdown: no rounds traced"
    lines = [
        f"phase breakdown over {bd['n_rounds']} rounds "
        f"(mean round {bd['mean_round_s'] * 1e3:.2f} ms, "
        f"span coverage mean={bd['coverage_mean']:.1%} min={bd['coverage_min']:.1%})"
    ]
    for name in ROUND_PHASES:
        lines.append(f"  {name:15s} {bd['phase_s'][name] * 1e3:9.2f} ms "
                     f"{bd['phase_frac'][name]:6.1%}")
    lines.append(f"  {'~ kv_move':15s} {bd['kv_move_s'] * 1e3:9.2f} ms "
                 f"{bd['kv_move_frac']:6.1%}  (nested in the phases above)")
    lines.append(
        f"  => draft {bd['draft_frac']:.1%} / verify {bd['verify_frac']:.1%} "
        f"/ absorb {bd['absorb_frac']:.1%} of round wall time"
    )
    lines.append(
        f"  => draft overlapped with verify {bd['overlap_draft_verify_s'] * 1e3:.2f} ms, "
        f"serialized {bd['draft_serialized_s'] * 1e3:.2f} ms "
        f"({bd['draft_serialized_frac']:.1%} of round)"
    )
    return "\n".join(lines)
