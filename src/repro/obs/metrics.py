"""MetricsRegistry — counters, gauges, histograms, and bounded sample series
with per-replica labels.

The registry is the fleet's numeric state (the tracer is its timeline):
per-replica round counters, the accepted-depth distribution the adaptive-
depth scheduler (ROADMAP #2) will read, queue-depth-over-time samples, TTFT
histograms, KV-budget truncation counts.  Handles are get-or-create keyed by
``(name, labels)`` — ask twice, get the same object — so instrument points
cache a handle once and touch only that object on the hot path.

Export: ``snapshot()`` is the structured dict (what ``--metrics-out``
writes); ``to_prometheus()`` is the standard text exposition format
(cumulative ``_bucket``/``_sum``/``_count`` lines for histograms, last
value for series).
"""

from __future__ import annotations

import collections
import json


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are ascending upper bounds, with
    an implicit +Inf bucket; ``counts[i]`` is the NON-cumulative count of
    observations <= buckets[i] (cumulation happens at export)."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets):
        b = tuple(float(x) for x in buckets)
        if not b or any(y <= x for x, y in zip(b, b[1:])):
            raise ValueError(f"buckets must be non-empty ascending, got {buckets}")
        self.buckets = b
        self.counts = [0] * (len(b) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, x: float) -> None:
        x = float(x)
        self.sum += x
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if x <= ub:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class Series:
    """Bounded (timestamp, value) samples — 'X over time' (queue depth,
    occupancy) where a histogram would lose the trajectory."""

    __slots__ = ("samples", "dropped")

    def __init__(self, maxlen: int = 4096):
        self.samples: collections.deque = collections.deque(maxlen=maxlen)
        self.dropped = 0

    def append(self, t: float, value: float) -> None:
        if len(self.samples) == self.samples.maxlen:
            self.dropped += 1
        self.samples.append((t, value))

    @property
    def last(self) -> float | None:
        return self.samples[-1][1] if self.samples else None

    def values(self) -> list[float]:
        return [v for _, v in self.samples]


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}" if key else ""


class MetricsRegistry:
    def __init__(self):
        # kind -> name -> label_key -> metric object
        self._m: dict[str, dict[str, dict[tuple, object]]] = {
            "counter": {}, "gauge": {}, "histogram": {}, "series": {},
        }

    def _get(self, kind: str, name: str, labels: dict, make):
        fam = self._m[kind].setdefault(name, {})
        key = _label_key(labels)
        got = fam.get(key)
        if got is None:
            got = fam[key] = make()
        return got

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, buckets=(0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                                            0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0),
                  **labels) -> Histogram:
        """Get-or-create; ``buckets`` only applies on first creation (the
        family keeps its original bucket layout)."""
        return self._get("histogram", name, labels, lambda: Histogram(buckets))

    def series(self, name: str, maxlen: int = 4096, **labels) -> Series:
        return self._get("series", name, labels, lambda: Series(maxlen))

    def histogram_family(self, name: str) -> list[tuple[dict, Histogram]]:
        """Every (labels, histogram) pair registered under ``name`` — e.g.
        the per-replica ``serving_accept_depth`` family, for fleet-level
        merging with ``merge_histograms``.  Read-only: does not create."""
        fam = self._m["histogram"].get(name, {})
        return [(dict(key), h) for key, h in sorted(fam.items())]

    def series_family(self, name: str) -> list[tuple[dict, Series]]:
        """Every (labels, series) pair registered under ``name`` — e.g. the
        per-replica ``serving_round_depth`` family.  Read-only: does not
        create."""
        fam = self._m["series"].get(name, {})
        return [(dict(key), s) for key, s in sorted(fam.items())]

    # ---- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Structured dump of every metric (the ``--metrics-out`` payload)."""
        out: dict = {"counters": [], "gauges": [], "histograms": [], "series": []}
        for name, fam in sorted(self._m["counter"].items()):
            for key, c in sorted(fam.items()):
                out["counters"].append(
                    {"name": name, "labels": dict(key), "value": c.value})
        for name, fam in sorted(self._m["gauge"].items()):
            for key, g in sorted(fam.items()):
                out["gauges"].append(
                    {"name": name, "labels": dict(key), "value": g.value})
        for name, fam in sorted(self._m["histogram"].items()):
            for key, h in sorted(fam.items()):
                out["histograms"].append({
                    "name": name, "labels": dict(key),
                    "buckets": list(h.buckets), "counts": list(h.counts),
                    "sum": h.sum, "count": h.count, "mean": h.mean,
                })
        for name, fam in sorted(self._m["series"].items()):
            for key, s in sorted(fam.items()):
                out["series"].append({
                    "name": name, "labels": dict(key),
                    "samples": [[t, v] for t, v in s.samples],
                    "dropped": s.dropped,
                })
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (series render as last-value gauges)."""
        lines: list[str] = []
        for name, fam in sorted(self._m["counter"].items()):
            lines.append(f"# TYPE {name} counter")
            for key, c in sorted(fam.items()):
                lines.append(f"{name}{_label_str(key)} {_fmt(c.value)}")
        for name, fam in sorted(self._m["gauge"].items()):
            lines.append(f"# TYPE {name} gauge")
            for key, g in sorted(fam.items()):
                lines.append(f"{name}{_label_str(key)} {_fmt(g.value)}")
        for name, fam in sorted(self._m["histogram"].items()):
            lines.append(f"# TYPE {name} histogram")
            for key, h in sorted(fam.items()):
                cum = 0
                for ub, c in zip(h.buckets, h.counts):
                    cum += c
                    lk = _label_key({**dict(key), "le": _fmt(ub)})
                    lines.append(f"{name}_bucket{_label_str(lk)} {cum}")
                lk = _label_key({**dict(key), "le": "+Inf"})
                lines.append(f"{name}_bucket{_label_str(lk)} {h.count}")
                lines.append(f"{name}_sum{_label_str(key)} {_fmt(h.sum)}")
                lines.append(f"{name}_count{_label_str(key)} {h.count}")
        for name, fam in sorted(self._m["series"].items()):
            lines.append(f"# TYPE {name} gauge")
            for key, s in sorted(fam.items()):
                if s.samples:
                    lines.append(f"{name}{_label_str(key)} {_fmt(s.last)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write(self, path: str, extra: dict | None = None) -> str:
        """Write the snapshot as JSON (``.prom`` → Prometheus text).  ``extra``
        merges additional top-level sections (e.g. a phase breakdown)."""
        with open(path, "w") as f:
            if path.endswith(".prom"):
                f.write(self.to_prometheus())
            else:
                payload = self.snapshot()
                if extra:
                    payload.update(extra)
                json.dump(payload, f, indent=1)
        return path


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def merge_histograms(hists) -> Histogram:
    """Merge histograms that may have DIFFERENT bucket layouts: the result's
    buckets are the sorted union of every source's upper bounds, each source
    bucket's count lands at the union bucket with the same upper bound, and
    +Inf counts stay in +Inf.  Lossless in the Prometheus sense — an
    observation counted "<= ub" at the source is still counted "<= ub" in
    the merge (replicas running different draft depths have different
    ``serving_accept_depth`` edges; summing counts positionally would
    misfile them)."""
    hists = list(hists)
    if not hists:
        raise ValueError("need at least one histogram to merge")
    edges = sorted({ub for h in hists for ub in h.buckets})
    out = Histogram(edges)
    pos = {ub: i for i, ub in enumerate(edges)}
    for h in hists:
        for ub, c in zip(h.buckets, h.counts):
            out.counts[pos[ub]] += c
        out.counts[-1] += h.counts[-1]
        out.sum += h.sum
        out.count += h.count
    return out
