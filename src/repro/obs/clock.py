"""The sanctioned monotonic clock — every raw wall-clock read lives here.

Timing in this codebase flows through one of two doors: the *injectable*
clocks (``repro.serving.runtime.WallClock`` / ``VirtualClock``) for anything
on the serving timeline, and ``monotonic()`` below for one-off stopwatch
measurements (generate() wall time, profile passes, compile timing).  The
static-analysis CLOCK rule (docs/static-analysis.md) bans ``time.time`` /
``time.perf_counter`` / friends everywhere else, so VirtualClock benchmarks
stay deterministic and no non-monotonic ``time.time()`` can sneak into a
latency column again (launch/dryrun.py used to do exactly that).
"""

from __future__ import annotations

import time


def monotonic() -> float:
    """Monotonic fractional seconds; the process-wide stopwatch timebase."""
    # the single sanctioned raw read the CLOCK rule allows
    return time.perf_counter()  # repro: disable=CLOCK — this IS the abstraction


class Stopwatch:
    """Tiny elapsed-time helper for the launch/benchmark drivers::

        sw = Stopwatch()
        ...work...
        dt = sw.lap()      # seconds since construction or the last lap
        total = sw.total() # seconds since construction
    """

    def __init__(self):
        self._t0 = self._last = monotonic()

    def lap(self) -> float:
        now = monotonic()
        dt, self._last = now - self._last, now
        return dt

    def total(self) -> float:
        return monotonic() - self._t0
