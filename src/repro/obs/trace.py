"""Phase tracer — ring-buffered spans with monotonic timestamps.

The serving stack's latency argument (draft expansion vs. target
verification vs. KV reconciliation inside each round — the paper's
decomposition) needs *where-did-the-milliseconds-go* evidence, not just
end-of-run aggregates.  ``Tracer`` records host-side phase spans:

  * ``begin(name, track)`` / ``Span.end()`` — explicit span lifetime (used
    where begin and end live in different methods, e.g. a round span opened
    by ``EngineStepper.step`` and closed by ``absorb_round``);
  * ``span(name, track)`` — the same span as a context manager;
  * ``instant(name)`` / ``counter(name, value)`` — point events and
    time-series counters (queue depth, occupancy).

Disabled-path contract: a disabled tracer is free.  ``begin``/``span``
return the cached ``NOOP_SPAN`` singleton before touching the clock, so the
per-round hot path allocates nothing and pays two attribute loads + a
branch (tests/test_obs.py asserts zero traced allocation).  ``NULL_TRACER``
is the shared inert default every runtime falls back to.

Storage is a bounded ``deque`` per event kind (oldest spans drop first,
counted in ``dropped``), so a long-running server cannot grow without
bound.  Export: ``to_chrome()`` emits the Chrome/Perfetto ``traceEvents``
JSON (open in ``ui.perfetto.dev`` or ``chrome://tracing``); ``write(path)``
picks Chrome JSON or span-per-line JSONL from the file extension.

Timestamps are ``repro.obs.clock.monotonic()`` (fractional seconds)
relative to tracer construction; the clock is injectable for deterministic
tests.
"""

from __future__ import annotations

import collections
import json

from repro.obs.clock import monotonic


class _NoopSpan:
    """Inert span: the single cached object every disabled call returns."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def end(self) -> None:
        pass

    def set(self, key, value) -> None:
        pass


NOOP_SPAN = _NoopSpan()


class Span:
    """One finished or in-flight phase span on one track."""

    __slots__ = ("_tracer", "name", "track", "t0", "t1", "args")

    def __init__(self, tracer: "Tracer", name: str, track: str, t0: float, args):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.t0 = t0
        self.t1 = None
        self.args = args

    @property
    def dur(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def set(self, key, value) -> None:
        """Attach one arg after creation (e.g. a routing decision made
        mid-span)."""
        if self.args is None:
            self.args = {}
        self.args[key] = value

    def end(self) -> None:
        if self.t1 is None:
            self.t1 = self._tracer._now()
            self._tracer._finish(self)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.end()
        return False


class Tracer:
    def __init__(self, capacity: int = 1 << 16, enabled: bool = True, clock=None):
        self.enabled = enabled
        self.capacity = capacity
        self.dropped = 0
        self._clock = clock if clock is not None else monotonic
        self._epoch = self._clock()
        self._spans: collections.deque[Span] = collections.deque(maxlen=capacity)
        self._instants: collections.deque = collections.deque(maxlen=capacity)
        self._counters: collections.deque = collections.deque(maxlen=capacity)
        self._tracks: dict[str, int] = {}

    # ---- recording -------------------------------------------------------
    def _now(self) -> float:
        return self._clock() - self._epoch

    def _finish(self, span: Span) -> None:
        if len(self._spans) == self.capacity:
            self.dropped += 1
        self._spans.append(span)

    def begin(self, name: str, track: str = "main", args=None):
        """Open a span; close it with ``.end()`` (or use it as a context
        manager).  Disabled: returns the cached no-op singleton."""
        if not self.enabled:
            return NOOP_SPAN
        return Span(self, name, track, self._now(), args)

    # a span used inline reads better as ``with tracer.span(...):``
    span = begin

    def instant(self, name: str, track: str = "main", args=None) -> None:
        if not self.enabled:
            return
        if len(self._instants) == self.capacity:
            self.dropped += 1
        self._instants.append((name, track, self._now(), args))

    def counter(self, name: str, value, track: str = "counters") -> None:
        """One sample of a time-series counter (queue depth, occupancy)."""
        if not self.enabled:
            return
        if len(self._counters) == self.capacity:
            self.dropped += 1
        self._counters.append((name, track, self._now(), value))

    # ---- reading ---------------------------------------------------------
    def spans(self, name: str | None = None) -> list[Span]:
        """Finished spans in completion order (optionally one name)."""
        if name is None:
            return list(self._spans)
        return [s for s in self._spans if s.name == name]

    def counters(self, name: str | None = None) -> list:
        if name is None:
            return list(self._counters)
        return [c for c in self._counters if c[0] == name]

    # ---- export ----------------------------------------------------------
    def _tid(self, track: str) -> int:
        return self._tracks.setdefault(track, len(self._tracks))

    def to_chrome(self) -> dict:
        """Chrome/Perfetto ``traceEvents`` JSON (timestamps in µs)."""
        events = []
        for s in self._spans:
            ev = {"name": s.name, "cat": "phase", "ph": "X", "pid": 0,
                  "tid": self._tid(s.track),
                  "ts": s.t0 * 1e6, "dur": s.dur * 1e6}
            if s.args:
                ev["args"] = s.args
            events.append(ev)
        for name, track, t, args in self._instants:
            ev = {"name": name, "cat": "event", "ph": "i", "s": "t",
                  "pid": 0, "tid": self._tid(track), "ts": t * 1e6}
            if args:
                ev["args"] = args
            events.append(ev)
        for name, track, t, value in self._counters:
            events.append({"name": name, "cat": "counter", "ph": "C", "pid": 0,
                           "tid": self._tid(track), "ts": t * 1e6,
                           "args": {name: value}})
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                 "args": {"name": track}}
                for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1])]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def to_jsonl(self) -> str:
        """One finished span per line: name, track, t0/t1/dur (seconds)."""
        lines = []
        for s in self._spans:
            rec = {"name": s.name, "track": s.track,
                   "t0": s.t0, "t1": s.t1, "dur": s.dur}
            if s.args:
                rec["args"] = s.args
            lines.append(json.dumps(rec))
        return "\n".join(lines) + ("\n" if lines else "")

    def write(self, path: str) -> str:
        """Dump the trace: ``.jsonl`` → span-per-line, anything else →
        Chrome ``traceEvents`` JSON."""
        with open(path, "w") as f:
            if path.endswith(".jsonl"):
                f.write(self.to_jsonl())
            else:
                json.dump(self.to_chrome(), f)
        return path


# the shared inert default: every instrument point falls back to this, so
# an un-instrumented run pays only the disabled-path branch
NULL_TRACER = Tracer(capacity=0, enabled=False)
