"""int8 gradient compression for the cross-pod (DCN) all-reduce.

The pod axis is the slow domain (DCN, not ICI), so the pod-axis gradient
all-reduce is the one worth compressing: per-tensor symmetric int8 with an
f32 scale cuts DCN bytes 4× at <0.5% relative error on gradient-scale
tensors.  Error is bounded by quantizing AFTER the fast intra-pod reduction
and summing dequantized values (no bias accumulation across steps here; for
momentum-safe training the residual could be carried, noted in DESIGN.md).

``pod_allreduce_compressed`` is used inside shard_map'd train steps when
flags/config enable gradient compression.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """x (any float shape) -> (int8 tensor, f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def pod_allreduce_compressed(g, axis: str = "pod"):
    """Mean-reduce ``g`` over the pod axis with int8 payloads.

    int8 tensors cannot be psum'd losslessly per-shard, so the scheme is
    all-gather(int8 + scale) then local dequant-sum — for the 2-pod mesh this
    is exactly one DCN transfer of N/4 the f32 bytes.
    """
    q, scale = compress_int8(g)
    qs = jax.lax.all_gather(q, axis)  # [P, ...] int8
    ss = jax.lax.all_gather(scale, axis)  # [P]
    p = qs.shape[0]
    summed = jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0))
    return (summed / p).astype(g.dtype)
