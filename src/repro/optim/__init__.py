from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine
from repro.optim.compression import compress_int8, decompress_int8, pod_allreduce_compressed

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "compress_int8",
    "decompress_int8",
    "pod_allreduce_compressed",
]
