"""AdamW with f32 master copies and ZeRO-1-style sharded moments.

Moments inherit the parameter's NamedSharding from the same logical-axis
rules (sharding/rules.py), so under the production mesh the optimizer state
is automatically parameter-sharded (FSDP dim) — ZeRO-1 without a separate
partitioning pass.  Mixed precision: params may be bf16; masters and moments
are f32; the update casts back to the param dtype.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # i32
    mu: Any  # first moment, f32, param-tree
    nu: Any  # second moment, f32, param-tree
    master: Any  # f32 master params


def adamw_init(params) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=f32(params),
        nu=f32(params),
        # jnp.array (not astype): f32 params would alias master == param and
        # break buffer donation of (params, opt_state) pairs
        master=jax.tree.map(lambda x: jnp.array(x, jnp.float32), params),
    )


def adamw_update(grads, state: AdamWState, params, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state). ``lr`` is a scalar (schedule output)."""
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def upd(master, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return master - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * master)

    master = jax.tree.map(upd, state.master, mu, nu)
    new_params = jax.tree.map(lambda mstr, p: mstr.astype(p.dtype), master, params)
    return new_params, AdamWState(step=step, mu=mu, nu=nu, master=master)
