"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

The KV cache stores only the compressed latent ``c_kv`` plus the shared
rotary key ``k_rope`` — the MLA memory win.  Cached mode uses the *absorbed*
formulation (W_uk folded into the query, W_uv applied after the probability-
weighted latent sum), so decode never materializes per-head K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, ones_init, rms_norm
from repro.models.attention import scatter_rows
from repro.sharding import constrain


def init_mla(cfg, key):
    d, H = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_dq": dense_init(ks[0], (d, ql), ("embed", "lora"), dt),
        "q_norm": ones_init((ql,), ("lora",), dt),
        "w_uq": dense_init(ks[1], (ql, H, nd + rd), ("lora", "heads", "qk_dim"), dt),
        "w_dkv": dense_init(ks[2], (d, kvl + rd), ("embed", "lora"), dt),
        "kv_norm": ones_init((kvl,), ("lora",), dt),
        "w_uk": dense_init(ks[3], (kvl, H, nd), ("lora", "heads", "qk_dim"), dt),
        "w_uv": dense_init(ks[4], (kvl, H, vd), ("lora", "heads", "head_dim"), dt),
        "wo": dense_init(ks[5], (H, vd, d), ("heads", "head_dim", "embed"), dt, scale=(H * vd) ** -0.5),
    }


def _queries(cfg, p, x, positions):
    nd, rd = cfg.nope_head_dim, cfg.rope_head_dim
    q_lat = rms_norm(x @ p["w_dq"].value, p["q_norm"].value, cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", q_lat, p["w_uq"].value)  # [B,S,H,nd+rd]
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(cfg, p, x, positions):
    kvl, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    lat = x @ p["w_dkv"].value  # [B,S,kvl+rd]
    c_kv = rms_norm(lat[..., :kvl], p["kv_norm"].value, cfg.norm_eps)
    k_rope = apply_rope(lat[..., None, kvl:], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_full(cfg, p, x, positions):
    """Train/prefill MLA: materialized K/V, causal, q-chunked.

    Returns (out, (c_kv, k_rope)) for cache population.
    """
    from repro.flags import get_flags

    B, S, _ = x.shape
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(cfg, p, x, positions)
    c_kv, k_rope = _latents(cfg, p, x, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"].value)
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uv"].value)
    k_nope = constrain(k_nope, "batch", "seq", "heads", "qk_dim")
    v = constrain(v, "batch", "seq", "heads", "head_dim")
    scale = 1.0 / jnp.sqrt(nd + rd)

    chunk = min(get_flags().attn_chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def one_chunk(ci):
        qn = jax.lax.dynamic_slice_in_dim(q_nope, ci * chunk, chunk, axis=1)
        qr = jax.lax.dynamic_slice_in_dim(q_rope, ci * chunk, chunk, axis=1)
        pos_q = jax.lax.dynamic_slice_in_dim(positions, ci * chunk, chunk, axis=1)
        scores = (
            jnp.einsum("bnhk,bshk->bhns", qn, k_nope)
            + jnp.einsum("bnhk,bsk->bhns", qr, k_rope)
        ) * scale
        mask = positions[:, None, :] <= pos_q[:, :, None]  # [B,c,S]
        scores = jnp.where(mask[:, None, :, :], scores.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhns,bshk->bnhk", probs, v)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        # per-chunk checkpoint: recompute probs in backward (see attention.py)
        outs = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, cfg.n_heads, vd)
    out = jnp.einsum("bnhk,hkd->bnd", out, p["wo"].value)
    return constrain(out, "batch", "seq", "act_embed"), (c_kv, k_rope)


def mla_cached(cfg, p, x, cache_ckv, cache_krope, row_idx, positions, attn_mask, *,
               row_start=None):
    """Cached MLA (decode / spec tree), absorbed form. Returns (out, ckv', krope')."""
    from repro.models.attention import update_rows_contiguous

    nd, rd = cfg.nope_head_dim, cfg.rope_head_dim
    q_nope, q_rope = _queries(cfg, p, x, positions)
    c_new, kr_new = _latents(cfg, p, x, positions)
    if row_start is not None:  # contiguous decode/chain fast path
        ckv = update_rows_contiguous(cache_ckv, c_new, row_start)
        krope = update_rows_contiguous(cache_krope, kr_new, row_start)
    else:
        ckv = scatter_rows(cache_ckv, c_new, row_idx)
        krope = scatter_rows(cache_krope, kr_new, row_idx)
    ckv = constrain(ckv, "cache_batch", "kv_seq", None)
    krope = constrain(krope, "cache_batch", "kv_seq", None)

    # absorbed: q_eff[h] = q_nope[h] @ W_uk[h]^T -> dot with latent directly
    q_eff = jnp.einsum("bnhk,lhk->bnhl", q_nope, p["w_uk"].value)
    scale = 1.0 / jnp.sqrt(nd + rd)
    scores = (
        jnp.einsum("bnhl,bsl->bhns", q_eff, ckv)
        + jnp.einsum("bnhk,bsk->bhns", q_rope, krope)
    ) * scale
    scores = jnp.where(attn_mask[:, None, :, :], scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.any(attn_mask[:, None, :, :], axis=-1, keepdims=True), probs, 0.0)
    lat_sum = jnp.einsum("bhns,bsl->bnhl", probs.astype(ckv.dtype), ckv)
    out = jnp.einsum("bnhl,lhk->bnhk", lat_sum, p["w_uv"].value)
    out = jnp.einsum("bnhk,hkd->bnd", out, p["wo"].value)
    return out, ckv, krope
