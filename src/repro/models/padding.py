"""Arbitrary-TP zero-padding (paper §4).

``resolve_for_tp`` (configs/base.py) widens head counts / ff dims so every
matmul splits across the mesh's TP degree; ``pad_params`` embeds an existing
model's weights into the widened parameter tree with zeros.

Zero padding is output-equivalent: padded ff columns contribute
silu(0)·0 = 0 through a zero-padded down-projection row, and padded attention
heads produce zero output through their zero-padded o-projection rows —
exactly the paper's construction (tests/test_sharding.py asserts equality).

GQA subtlety: query heads are grouped per KV head (``g = Hq/Hkv``), so
padding must interleave new slots WITHIN each group — old head (k·g + j)
lands at (k·g' + j) — or the widened reshape would re-pair queries with the
wrong KV heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import Param


def _head_map(hq_old: int, hq_new: int, hkv_old: int, hkv_new: int):
    """old query-head index -> new index, preserving KV grouping.

    Old head (k·g_old + j) lands at (k·g_new + j); when the padding widened
    the KV heads at fixed g this is the identity (tail padding)."""
    if hkv_old <= 0 or hq_old % hkv_old or hkv_new <= 0 or hq_new % hkv_new:
        return jnp.arange(hq_old)
    g_old, g_new = hq_old // hkv_old, hq_new // hkv_new
    k = jnp.arange(hq_old) // g_old
    j = jnp.arange(hq_old) % g_old
    return k * g_new + j


def pad_params(cfg_small, cfg_big, params_small, params_big):
    """Embed ``params_small`` into the zero-initialized ``params_big`` tree
    (which supplies target shapes, e.g. an init of the resolve_for_tp'd
    config).  Returns the zero-padded tree."""
    hmap = _head_map(cfg_small.n_heads, cfg_big.n_heads,
                     cfg_small.n_kv_heads, cfg_big.n_kv_heads)

    def one(ps: Param, pb: Param):
        a, b = ps.value, pb.value
        assert a.ndim == b.ndim, (a.shape, b.shape)
        out = jnp.zeros(b.shape, b.dtype)
        idx = []
        for d, (sa, ax) in enumerate(zip(a.shape, ps.axes)):
            if ax == "heads" and sa == cfg_small.n_heads and b.shape[d] == cfg_big.n_heads:
                idx.append(d)
        val = a.astype(b.dtype)
        if not idx:
            return Param(out.at[tuple(slice(0, s) for s in a.shape)].set(val), pb.axes)
        # scatter grouped head slots (one heads dim per param in this zoo)
        (d,) = idx
        moved = jnp.moveaxis(val, d, 0)
        tgt = jnp.moveaxis(out, d, 0)
        lead = tuple(slice(0, s) for s in moved.shape[1:])
        tgt = tgt.at[(hmap,) + lead].set(moved)
        return Param(jnp.moveaxis(tgt, 0, d), pb.axes)

    return jax.tree.map(one, params_small, params_big,
                        is_leaf=lambda x: isinstance(x, Param))
