"""Shared model building blocks: init helpers, RMSNorm, RoPE, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import Param


def dense_init(key, shape, axes, dtype, scale=None):
    """Truncated-normal init boxed with logical axes."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else fan_in**-0.5
    val = std * jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
    return Param(val.astype(dtype), tuple(axes))


def zeros_init(shape, axes, dtype):
    return Param(jnp.zeros(shape, dtype), tuple(axes))


def ones_init(shape, axes, dtype):
    return Param(jnp.ones(shape, dtype), tuple(axes))


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """Rotary embedding. x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., seq, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down, b_gate=None, b_up=None):
    """SwiGLU(x) = (silu(x W_g + b_g) * (x W_u + b_u)) W_d.

    This is the op the ``fused_swiglu`` Pallas kernel implements in one HBM
    pass (paper §3.3); here in composable jnp form for XLA fusion.
    """
    g = x @ w_gate
    u = x @ w_up
    if b_gate is not None:
        g = g + b_gate
    if b_up is not None:
        u = u + b_up
    return (jax.nn.silu(g) * u) @ w_down


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token CE. logits: [..., vocab] (may be vocab-sharded under pjit)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
