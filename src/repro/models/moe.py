"""Mixture-of-Experts: capacity-based grouped dispatch, jittable & shardable.

Two execution strategies (flags.moe_impl):
  "tp" — TP-within-expert (default): expert weights replicated across "model"
         on the expert dim, sharded on the ff dim.  Dispatch is local to each
         data shard; the only collective is the same psum a dense MLP needs.
  "ep" — expert-parallel: experts sharded across "model"; each model shard
         computes the full-ff MLP of its own experts for the (replicated)
         local tokens and a psum combines contributions.  Evaluated against
         "tp" in the §Perf hillclimb.

Dispatch is the sort-based capacity scheme: (token, k) pairs are sorted by
expert id, positions-within-expert beyond capacity drop (weighted renorm keeps
the estimator unbiased enough for routing studies; capacity_factor controls
drops).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.flags import get_flags
from repro.models.common import dense_init
from repro.sharding import get_mesh, shard_map


def init_moe(cfg, key):
    E = cfg.n_experts
    dff = cfg.moe_d_ff or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "router": dense_init(ks[0], (d, E), ("embed", None), dt),
        "wg": dense_init(ks[1], (E, d, dff), ("experts", "embed", "ff"), dt),
        "wu": dense_init(ks[2], (E, d, dff), ("experts", "embed", "ff"), dt),
        "wd": dense_init(ks[3], (E, dff, d), ("experts", "ff", "embed"), dt),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * dff
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": dense_init(ks2[0], (d, sff), ("embed", "ff"), dt),
            "wu": dense_init(ks2[1], (d, sff), ("embed", "ff"), dt),
            "wd": dense_init(ks2[2], (sff, d), ("ff", "embed"), dt),
        }
    return p


def _dispatch(x2d, router_w, n_experts, top_k, capacity):
    """Route tokens to per-expert slots. Returns (xbuf [E,C,d], combine info)."""
    T, d = x2d.shape
    gates = jax.nn.softmax((x2d.astype(jnp.float32)) @ router_w.astype(jnp.float32))
    topv, topi = jax.lax.top_k(gates, top_k)  # [T,k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)  # [T*k]
    sort_idx = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[sort_idx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    pos_in_e = jnp.arange(T * top_k) - seg_start[sorted_e]
    keep = pos_in_e < capacity
    dest = jnp.where(keep, sorted_e * capacity + pos_in_e, n_experts * capacity)
    tok = sort_idx // top_k

    xbuf = jnp.zeros((n_experts * capacity + 1, d), x2d.dtype).at[dest].add(x2d[tok])
    w_sorted = topv.reshape(-1)[sort_idx] * keep
    return xbuf[:-1].reshape(n_experts, capacity, d), (dest, tok, w_sorted)


def _combine(h, info, T):
    dest, tok, w_sorted = info
    E_C, d = h.reshape(-1, h.shape[-1]).shape
    hflat = jnp.concatenate([h.reshape(E_C, d), jnp.zeros((1, d), h.dtype)], 0)
    contrib = hflat[dest] * w_sorted[:, None].astype(h.dtype)
    return jnp.zeros((T, d), h.dtype).at[tok].add(contrib)


def _expert_mlp(xbuf, wg, wu, wd):
    g = jnp.einsum("ecd,edf->ecf", xbuf, wg)
    u = jnp.einsum("ecd,edf->ecf", xbuf, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


def _moe_local(x2d, p_vals, cfg, capacity):
    xbuf, info = _dispatch(x2d, p_vals["router"], cfg.n_experts, cfg.moe_top_k, capacity)
    h = _expert_mlp(xbuf, p_vals["wg"], p_vals["wu"], p_vals["wd"])
    return _combine(h, info, x2d.shape[0])


def moe_apply(cfg, p, x):
    """x: [B, S, d] (or [B, n, d]); returns same shape."""
    flags = get_flags()
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    mesh = get_mesh()
    p_vals = {k: v.value for k, v in p.items() if k != "shared"}

    if mesh is None or "model" not in mesh.axis_names:
        T = x2d.shape[0]
        cap = max(1, math.ceil(T * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor))
        out = _moe_local(x2d, p_vals, cfg, cap)
    else:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dsize = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
        msize = mesh.shape["model"]
        T_local = max(1, (B * S) // max(dsize, 1))
        cap = max(1, math.ceil(T_local * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor))
        tok_spec = P(data_axes if data_axes else None, None)

        if flags.moe_impl == "ep" and cfg.n_experts % msize == 0:
            # expert-parallel: shard experts over "model"; tokens replicated on
            # "model"; each shard computes its experts' full-ff MLP; psum merges.
            e_loc = cfg.n_experts // msize

            def ep_block(x_loc, router, wg, wu, wd):
                midx = jax.lax.axis_index("model")
                gates = jax.nn.softmax(x_loc.astype(jnp.float32) @ router.astype(jnp.float32))
                topv, topi = jax.lax.top_k(gates, cfg.moe_top_k)
                topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
                # local expert ids owned by this shard: [midx*e_loc, (midx+1)*e_loc)
                rel = topi - midx * e_loc  # [T,k]
                mine = (rel >= 0) & (rel < e_loc)
                flat_e = jnp.where(mine, rel, e_loc).reshape(-1)
                sort_idx = jnp.argsort(flat_e)
                sorted_e = flat_e[sort_idx]
                seg_start = jnp.searchsorted(sorted_e, jnp.arange(e_loc))
                cap_ep = max(1, math.ceil(T_local * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor))
                pos_in_e = jnp.arange(flat_e.shape[0]) - seg_start[sorted_e.clip(0, e_loc - 1)]
                keep = (sorted_e < e_loc) & (pos_in_e < cap_ep)
                dest = jnp.where(keep, sorted_e * cap_ep + pos_in_e, e_loc * cap_ep)
                tok = sort_idx // cfg.moe_top_k
                xbuf = jnp.zeros((e_loc * cap_ep + 1, d), x_loc.dtype).at[dest].add(x_loc[tok])
                h = _expert_mlp(xbuf[:-1].reshape(e_loc, cap_ep, d), wg, wu, wd)
                w_sorted = (topv.reshape(-1)[sort_idx] * keep).astype(h.dtype)
                y = _combine(h, (dest, tok, w_sorted), x_loc.shape[0])
                return jax.lax.psum(y, "model")

            out = shard_map(
                ep_block,
                mesh=mesh,
                in_specs=(tok_spec, P(None, None), P("model", None, None), P("model", None, None), P("model", None, None)),
                out_specs=tok_spec,
                check_vma=False,
            )(x2d, p_vals["router"], p_vals["wg"], p_vals["wu"], p_vals["wd"])
        else:
            # TP-within-expert: ff dim sharded over "model"; dispatch local.
            def tp_block(x_loc, router, wg, wu, wd):
                y = _moe_local(x_loc, {"router": router, "wg": wg, "wu": wu, "wd": wd}, cfg, cap)
                return jax.lax.psum(y, "model")

            out = shard_map(
                tp_block,
                mesh=mesh,
                in_specs=(tok_spec, P(None, None), P(None, None, "model"), P(None, None, "model"), P(None, "model", None)),
                out_specs=tok_spec,
                check_vma=False,
            )(x2d, p_vals["router"], p_vals["wg"], p_vals["wu"], p_vals["wd"])

    if cfg.n_shared_experts:
        sh = p["shared"]
        g = x2d @ sh["wg"].value
        u = x2d @ sh["wu"].value
        out = out + (jax.nn.silu(g) * u) @ sh["wd"].value

    return out.reshape(B, S, d)
