"""Mamba2 (SSD) block: chunked state-space scan for train/prefill, masked-
commit chain mode for speculative verification on state models (DESIGN.md §6).

Projections are split per segment (z / x / BC / dt) instead of one fused
in_proj so each shards cleanly: d_in and heads over "model", the small B/C/dt
segments replicated.  State per layer: conv window [B, K-1, conv_dim] + SSM
state [B, H, hd, N] (f32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, ones_init, rms_norm, zeros_init
from repro.sharding import Param, constrain


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_in, nheads, conv_dim


def init_mamba2(cfg, key):
    d = cfg.d_model
    d_in, nheads, conv_dim = _dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    a0 = jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32))
    return {
        "w_z": dense_init(ks[0], (d, d_in), ("embed", "inner"), dt),
        "w_x": dense_init(ks[1], (d, d_in), ("embed", "inner"), dt),
        "w_bc": dense_init(ks[2], (d, 2 * G * N), ("embed", None), dt),
        "w_dt": dense_init(ks[3], (d, nheads), ("embed", "inner"), dt),
        "conv_wx": dense_init(ks[4], (cfg.ssm_conv, d_in), ("conv", "inner"), dt, scale=0.5),
        "conv_wbc": dense_init(ks[5], (cfg.ssm_conv, 2 * G * N), ("conv", None), dt, scale=0.5),
        "conv_b": zeros_init((conv_dim,), ("inner",), dt),
        "a_log": Param(a0.astype(dt), ("inner",)),
        "dt_bias": zeros_init((nheads,), ("inner",), dt),
        "d_skip": ones_init((nheads,), ("inner",), dt),
        "norm_w": ones_init((d_in,), ("inner",), dt),
        "out_proj": dense_init(jax.random.fold_in(ks[0], 7), (d_in, d), ("inner", "embed"), dt),
    }


def _causal_conv(x, w, b, window0=None):
    """Depthwise causal conv. x [B,S,C], w [K,C]. window0: [B,K-1,C] history."""
    B, S, C = x.shape
    K = w.shape[0]
    if window0 is None:
        window0 = jnp.zeros((B, K - 1, C), x.dtype)
    ext = jnp.concatenate([window0, x], axis=1)  # [B, K-1+S, C]
    out = sum(ext[:, i : i + S, :] * w[i] for i in range(K))
    return jax.nn.silu(out + b), ext


def _ssd_chunked(cfg, x, b, c, dt, a_log, d_skip, state0, chunk=64):
    """Chunked SSD scan.

    x: [B,S,H,hd]; b,c: [B,S,G,N]; dt: [B,S,H] (post-softplus, f32).
    Returns (y [B,S,H,hd], final state [B,H,hd,N] f32).
    """
    B, S, H, hd = x.shape
    G, N = b.shape[2], b.shape[3]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    rep = H // G
    a = -jnp.exp(a_log.astype(jnp.float32))  # [H]
    xr = x.reshape(B, nc, chunk, H, hd)
    br = jnp.repeat(b.reshape(B, nc, chunk, G, N), rep, axis=3)
    cr = jnp.repeat(c.reshape(B, nc, chunk, G, N), rep, axis=3)
    dtr = dt.reshape(B, nc, chunk, H)
    cum = jnp.cumsum(dtr * a, axis=2)  # inclusive cumsum of log-decays

    def step(state, inp):
        xc, bc, cc, dtc, cumc = inp  # [B,chunk,...]
        seg = cumc[:, :, None, :] - cumc[:, None, :, :]  # [B,i,j,H]
        tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])[None, :, :, None]
        # mask BEFORE exp: the j>i half has positive exponents that overflow,
        # and where(tri, exp, 0) backward would produce 0*inf = NaN grads
        L = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
        cb = jnp.einsum("bihn,bjhn->bijh", cc.astype(jnp.float32), bc.astype(jnp.float32))
        w = cb * L * dtc[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhd->bihd", w, xc.astype(jnp.float32))
        y_cross = jnp.einsum("bihn,bhdn->bihd", cc.astype(jnp.float32), state) * jnp.exp(cumc)[..., None]
        decay_to_end = jnp.exp(cumc[:, -1:, :] - cumc)  # [B,chunk,H]
        xw = xc.astype(jnp.float32) * (dtc * decay_to_end)[..., None]
        new_state = jnp.exp(cumc[:, -1, :])[:, :, None, None] * state + jnp.einsum(
            "bjhd,bjhn->bhdn", xw, bc.astype(jnp.float32)
        )
        return new_state, (y_intra + y_cross).astype(x.dtype)

    inps = tuple(jnp.moveaxis(t, 1, 0) for t in (xr, br, cr, dtr, cum))
    state_f, ys = jax.lax.scan(step, state0.astype(jnp.float32), inps)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    y = y + x * d_skip.astype(x.dtype)[None, None, :, None]
    return y, state_f


def _ssd_stepwise(cfg, x, b, c, dt, a_log, d_skip, state0, commit_mask):
    """Per-step SSD recurrence for chain-mode verification (S = chain length,
    small).  Equivalent math to the chunked scan; carries (full, committed)
    states so outputs stay teacher-forced while the returned state is the
    snapshot after exactly the committed prefix (cf. rwkv6._wkv_scan)."""
    B, S, H, hd = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    a = -jnp.exp(a_log.astype(jnp.float32))  # [H]
    br = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cr = jnp.repeat(c, rep, axis=2).astype(jnp.float32)

    def step(carry, inp):
        full, comm = carry
        xt, bt, ct, dtt, mt = inp  # [B,H,hd],[B,H,N],[B,H,N],[B,H],[B]
        decay = jnp.exp(dtt * a)  # [B,H]
        full = full * decay[..., None, None] + jnp.einsum(
            "bhd,bhn,bh->bhdn", xt.astype(jnp.float32), bt, dtt
        )
        yt = jnp.einsum("bhn,bhdn->bhd", ct, full)
        comm = jnp.where(mt[:, None, None, None], full, comm)
        return (full, comm), yt

    inps = tuple(jnp.moveaxis(t, 1, 0) for t in (x, br, cr, dt, commit_mask))
    s0 = state0.astype(jnp.float32)
    (_, state_c), ys = jax.lax.scan(step, (s0, s0), inps)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    y = y + x * d_skip.astype(x.dtype)[None, None, :, None]
    return y, state_c


def mamba2_apply(cfg, p, xin, cache=None, commit_mask=None):
    """Mamba2 block on [B,S,d] (S may be full seq, a decode step, or a chain).

    cache: {"conv": [B,K-1,conv_dim], "ssm": [B,H,hd,N]} or None (fresh).
    commit_mask [B,S]: if given, the returned cache corresponds to the masked
    prefix only (chain-mode rollback); outputs remain teacher-forced.
    Returns (out [B,S,d], new_cache).
    """
    B, S, _ = xin.shape
    d_in, nheads, conv_dim = _dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state
    K = cfg.ssm_conv
    z = xin @ p["w_z"].value
    x = xin @ p["w_x"].value
    bc = xin @ p["w_bc"].value
    dt_raw = xin @ p["w_dt"].value

    xbc = jnp.concatenate([x, bc], axis=-1)
    conv_w = jnp.concatenate([p["conv_wx"].value, p["conv_wbc"].value], axis=-1)
    window0 = cache["conv"] if cache is not None else None
    conv, ext = _causal_conv(xbc, conv_w, p["conv_b"].value, window0)

    if commit_mask is None:
        new_conv = ext[:, S:, :]  # ext has S+K-1 rows; keep the trailing window
    else:
        n_commit = jnp.sum(commit_mask.astype(jnp.int32), axis=1)  # [B]
        idx = n_commit[:, None] + jnp.arange(K - 1)[None, :]
        new_conv = jax.vmap(lambda e, i: e[i])(ext, idx)

    xc = conv[..., :d_in].reshape(B, S, nheads, cfg.ssm_head_dim)
    bcv = conv[..., d_in:]
    bv = bcv[..., : G * N].reshape(B, S, G, N)
    cv = bcv[..., G * N :].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].value.astype(jnp.float32))
    state0 = cache["ssm"] if cache is not None else jnp.zeros((B, nheads, cfg.ssm_head_dim, N), jnp.float32)
    if commit_mask is not None:
        # chain mode: per-step recurrence, committed-state snapshot
        y, state_f = _ssd_stepwise(cfg, xc, bv, cv, dt, p["a_log"].value,
                                   p["d_skip"].value, state0, commit_mask)
    else:
        y, state_f = _ssd_chunked(cfg, xc, bv, cv, dt, p["a_log"].value, p["d_skip"].value, state0)
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"].value, cfg.norm_eps)
    out = y @ p["out_proj"].value
    return constrain(out, "batch", "seq", "act_embed"), {"conv": new_conv, "ssm": state_f}


def init_mamba_cache(cfg, B, dtype):
    d_in, nheads, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((B, nheads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }
