"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The defining v6 feature — per-step, per-channel decay computed from the input
through a low-rank MLP — is implemented faithfully; the five per-projection
mixing LoRAs are simplified to static channel mixes (DESIGN.md §8).

State per layer: token-shift vectors (time-mix + channel-mix) and the WKV
matrix state [B, H, hd, hd] (f32).  Chain mode uses masked-commit like mamba2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, ones_init, zeros_init
from repro.sharding import Param, constrain

DECAY_LORA = 64


def _dims(cfg):
    hd = cfg.ssm_head_dim
    H = cfg.d_model // hd
    return H, hd


def init_rwkv6(cfg, key):
    d, ff = cfg.d_model, cfg.d_ff
    H, hd = _dims(cfg)
    ks = jax.random.split(key, 12)
    dt = jnp.dtype(cfg.param_dtype)
    u0 = jnp.zeros((H, hd), jnp.float32)
    return {
        # time-mix
        "mu_tm": Param(jnp.full((5, d), 0.5, dt), ("layers", "embed")),  # r,k,v,g,w mixes
        "w_r": dense_init(ks[0], (d, d), ("embed", "inner"), dt),
        "w_k": dense_init(ks[1], (d, d), ("embed", "inner"), dt),
        "w_v": dense_init(ks[2], (d, d), ("embed", "inner"), dt),
        "w_g": dense_init(ks[3], (d, d), ("embed", "inner"), dt),
        "w_o": dense_init(ks[4], (d, d), ("inner", "embed"), dt),
        "decay_base": Param(jnp.full((d,), -6.0, dt), ("inner",)),
        "decay_a": dense_init(ks[5], (d, DECAY_LORA), ("embed", "lora"), dt, scale=0.1),
        "decay_b": dense_init(ks[6], (DECAY_LORA, d), ("lora", "inner"), dt, scale=0.1),
        "bonus_u": Param(u0.astype(dt), ("inner", None)),
        "ln_x": ones_init((d,), ("inner",), dt),
        # channel-mix
        "mu_cm": Param(jnp.full((2, d), 0.5, dt), ("layers", "embed")),  # k,r mixes
        "cm_k": dense_init(ks[7], (d, ff), ("embed", "ff"), dt),
        "cm_v": dense_init(ks[8], (ff, d), ("ff", "embed"), dt),
        "cm_r": dense_init(ks[9], (d, d), ("embed", "inner"), dt),
    }


def _token_shift(x, last):
    """x [B,S,d], last [B,d] -> previous-token tensor [B,S,d]."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _wkv_chunked(r, k, v, logw, u, state0, chunk=32):
    """Chunked WKV-6 (§Perf B2): the segment-sum form of the recurrence.

    Same math as the per-step scan but with 1/chunk the state round-trips:
    within a chunk of length C, with L = cumsum(log w) (per k-channel),

      y_t      = Σ_k r_t[k]·e^{L_{t-1}[k]}·S_0[k,:]                (cross)
               + Σ_{j<t} Σ_k r_t[k]·k_j[k]·e^{L_{t-1}[k]-L_j[k]}·v_j  (intra)
               + (r_t·(u⊙k_t))·v_t                                  (bonus)
      S_C      = e^{L_C} ⊙ S_0 + Σ_j e^{L_C - L_j} ⊙ k_j ⊗ v_j

    All exponents are ≤ 0 (decays), masked BEFORE exp (cf. mamba2 NaN note).
    r,k,v: [B,S,H,hd]; logw: [B,S,H,hd] (≤0); state: [B,H,hd_k,hd_v] f32.
    """
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    rf, kf, vf, lw = (t.astype(jnp.float32).reshape(B, nc, chunk, H, hd)
                      for t in (r, k, v, logw))

    Lc = jnp.cumsum(lw, axis=2)  # inclusive decay log-sums
    Lprev = Lc - lw  # L_{t-1}

    tri = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])  # j < t

    def one_chunk(state, inp):
        rc, kc, vc, lc, lp = inp  # [B,chunk,H,hd]
        # cross: r decayed to chunk start, against the carried state
        y_cross = jnp.einsum("bthk,bhkv->bthv", rc * jnp.exp(lp), state)
        # intra: pairwise decay factors, masked before exp
        seg = lp[:, :, None] - lc[:, None, :]  # [B,t,j,H,hd]
        seg = jnp.where(tri[None, :, :, None, None], seg, 0.0)
        E = jnp.where(tri[None, :, :, None, None], jnp.exp(seg), 0.0)
        M = jnp.einsum("bthk,bjhk,btjhk->btjh", rc, kc, E)
        y_intra = jnp.einsum("btjh,bjhv->bthv", M, vc)
        y_bonus = jnp.einsum("bthk,bthv->bthv", rc * u[None, None] * kc, vc)
        # state to chunk end
        decay_end = jnp.exp(lc[:, -1:, :] - lc)  # e^{L_C - L_j}
        state = jnp.exp(lc[:, -1])[:, :, :, None] * state + jnp.einsum(
            "bjhk,bjhv->bhkv", kc * decay_end, vc)
        return state, y_cross + y_intra + y_bonus

    inps = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, Lc, Lprev))
    state_f, ys = jax.lax.scan(one_chunk, state0.astype(jnp.float32), inps)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    return y, state_f


def _wkv_scan(r, k, v, w, u, state0, commit_mask=None):
    """WKV-6 recurrence.

    r,k,v: [B,S,H,hd]; w: [B,S,H,hd] decay in (0,1); u: [H,hd] bonus.
    state: [B,H,hd(k),hd(v)].  y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);
    S_t = diag(w_t) S_{t-1} + k_t v_t^T.

    Outputs are always teacher-forced through the FULL recurrence; with a
    ``commit_mask`` the returned state is the snapshot after exactly the
    masked prefix (chain-mode speculation: wrong guesses never contaminate
    the committed state, yet every verification logit is exact).
    """
    B, S, H, hd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def advance(full, rt, kt, vt, wt):
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, full + u[None, :, :, None] * kv)
        return wt[..., None] * full + kv, yt

    s0 = state0.astype(jnp.float32)
    seq = lambda t: jnp.moveaxis(t, 1, 0)

    if commit_mask is None:  # train/prefill/decode: single state carry
        def step1(full, inp):
            full, yt = advance(full, *inp)
            return full, yt

        state_c, ys = jax.lax.scan(step1, s0, (seq(rf), seq(kf), seq(vf), seq(wf)))
    else:  # chain mode: (full, committed) pair
        def step2(carry, inp):
            full, comm = carry
            *rkvw, mt = inp
            full, yt = advance(full, *rkvw)
            comm = jnp.where(mt[:, None, None, None], full, comm)
            return (full, comm), yt

        (_, state_c), ys = jax.lax.scan(
            step2, (s0, s0), (seq(rf), seq(kf), seq(vf), seq(wf), seq(commit_mask))
        )
    return jnp.moveaxis(ys, 0, 1), state_c  # [B,S,H,hd], committed state


def rwkv6_time_mix(cfg, p, x, cache, commit_mask=None):
    """Returns (out [B,S,d], new_cache)."""
    B, S, d = x.shape
    H, hd = _dims(cfg)
    last = cache["sx_tm"] if cache is not None else jnp.zeros((B, d), x.dtype)
    prev = _token_shift(x, last)
    mu = p["mu_tm"].value  # [5,d]
    xr, xk, xv, xg, xw = (x + (prev - x) * mu[i] for i in range(5))
    r = (xr @ p["w_r"].value).reshape(B, S, H, hd)
    k = (xk @ p["w_k"].value).reshape(B, S, H, hd)
    v = (xv @ p["w_v"].value).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["w_g"].value)
    # data-dependent decay (the Finch feature): w = exp(-exp(base + lora(x)))
    dec = p["decay_base"].value.astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_a"].value) @ p["decay_b"].value
    ).astype(jnp.float32)
    logw = -jnp.exp(dec).reshape(B, S, H, hd)  # log-decay, always <= 0
    state0 = cache["wkv"] if cache is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
    uu = p["bonus_u"].value.astype(jnp.float32)
    if commit_mask is None and S >= 16:
        # chunked segment-sum form: 1/chunk the state round-trips (§Perf B2)
        y, state_f = _wkv_chunked(r, k, v, logw, uu, state0)
    else:
        y, state_f = _wkv_scan(r, k, v, jnp.exp(logw), uu, state0, commit_mask)
    y = y.reshape(B, S, d).astype(x.dtype)
    # group-norm substitute: per-head rms then learned scale
    yh = y.reshape(B, S, H, hd).astype(jnp.float32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-5)
    y = (yh.reshape(B, S, d) * p["ln_x"].value.astype(jnp.float32)).astype(x.dtype)
    out = (y * g) @ p["w_o"].value

    if commit_mask is not None:
        n_commit = jnp.sum(commit_mask.astype(jnp.int32), axis=1)  # [B]
        ext = jnp.concatenate([last[:, None, :], x], axis=1)  # [B,S+1,d]
        new_last = jax.vmap(lambda e, i: e[i])(ext, n_commit)
    else:
        new_last = x[:, -1, :]
    return constrain(out, "batch", "seq", "act_embed"), {"sx_tm": new_last, "wkv": state_f}


def rwkv6_channel_mix(cfg, p, x, cache, commit_mask=None):
    B, S, d = x.shape
    last = cache["sx_cm"] if cache is not None else jnp.zeros((B, d), x.dtype)
    prev = _token_shift(x, last)
    mu = p["mu_cm"].value
    xk = x + (prev - x) * mu[0]
    xr = x + (prev - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"].value))
    out = jax.nn.sigmoid(xr @ p["cm_r"].value) * (k @ p["cm_v"].value)
    if commit_mask is not None:
        n_commit = jnp.sum(commit_mask.astype(jnp.int32), axis=1)
        ext = jnp.concatenate([last[:, None, :], x], axis=1)
        new_last = jax.vmap(lambda e, i: e[i])(ext, n_commit)
    else:
        new_last = x[:, -1, :]
    return constrain(out, "batch", "seq", "act_embed"), {"sx_cm": new_last}


def init_rwkv_cache(cfg, B, dtype):
    H, hd = _dims(cfg)
    return {
        "sx_tm": jnp.zeros((B, cfg.d_model), dtype),
        "wkv": jnp.zeros((B, H, hd, hd), jnp.float32),
        "sx_cm": jnp.zeros((B, cfg.d_model), dtype),
    }
