"""Uniform Model API over every architecture in the zoo.

Entry points (all functional, all jittable):
  forward_train  — full causal forward -> logits (train_4k cells)
  prefill        — full forward + cache population (prefill_32k cells)
  decode_step    — one token against the cache (decode_32k / long_500k cells)
  spec_forward   — n tokens with an explicit NON-SQUARE tree mask (the paper's
                   draft-expansion / target-verification forward)
  chain_forward  — n chain tokens with masked state commit (SSM/hybrid
                   speculation; DESIGN.md §6)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    Ctx,
    apply_model,
    build_plan,
    embed_tokens,
    init_cache,
    init_model,
    logits_from_hidden,
)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- construction ----------------------------------------------------
    def init(self, key):
        return init_model(self.cfg, key)

    def init_cache(self, B, S_max, dtype=None):
        dt = jnp.dtype(dtype or self.cfg.dtype)
        return init_cache(self.cfg, B, S_max, dt)

    # ---- embedding helpers -------------------------------------------------
    def _embed(self, params, tokens=None, embeds=None):
        if embeds is not None:
            return embeds.astype(jnp.dtype(self.cfg.dtype))
        return embed_tokens(self.cfg, params, tokens)

    # ---- training ----------------------------------------------------------
    def forward_train(self, params, tokens=None, embeds=None, enc=None):
        h = self._embed(params, tokens, embeds)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(mode="full", positions=positions, enc=enc)
        h, _ = apply_model(self.cfg, params, h, ctx, cache=None)
        return logits_from_hidden(self.cfg, params, h)

    # ---- serving -----------------------------------------------------------
    def prefill(self, params, tokens=None, embeds=None, enc=None, S_max=None):
        """Returns (logits [B,S,V], cache with len=S)."""
        h = self._embed(params, tokens, embeds)
        B, S, _ = h.shape
        S_max = S_max or S
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(mode="full", make_cache=S_max, positions=positions, enc=enc)
        h, cache = apply_model(self.cfg, params, h, ctx, cache=None)
        cache["len"] = jnp.full((), S, jnp.int32)
        return logits_from_hidden(self.cfg, params, h), cache

    def spec_forward(self, params, cache, tokens, positions, row_idx, attn_mask):
        """Tree-structured forward: K/V written at ``row_idx``, attention under
        the non-square ``attn_mask`` [B,n,S_max]. ``cache['len']`` unchanged —
        the engine owns length bookkeeping (core/kv.py)."""
        h = self._embed(params, tokens)
        ctx = Ctx(mode="cached", positions=positions, row_idx=row_idx, attn_mask=attn_mask)
        h, nc = apply_model(self.cfg, params, h, ctx, cache=cache)
        nc["len"] = cache["len"]
        return logits_from_hidden(self.cfg, params, h), nc

    def chain_forward(self, params, cache, tokens, n_commit, S_max):
        """Chain-mode forward of n tokens starting at cache['len'].

        State blocks commit exactly ``n_commit`` steps (masked recurrence);
        attention blocks write rows [len, len+n) (rows beyond the committed
        point are dead and overwritten next round).  Returns (logits, cache')
        with cache'.len = len + n_commit.
        """
        B, n = tokens.shape
        start = cache["len"]
        positions = start + jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
        row_idx = positions
        cols = jnp.arange(S_max, dtype=jnp.int32)
        attn_mask = cols[None, None, :] <= positions[:, :, None]
        if self.cfg.sliding_window:
            attn_mask &= cols[None, None, :] > positions[:, :, None] - self.cfg.sliding_window
        commit = jnp.broadcast_to(jnp.arange(n) < n_commit, (B, n))
        h = self._embed(params, tokens)
        ctx = Ctx(
            mode="cached",
            positions=positions,
            row_idx=row_idx,
            attn_mask=attn_mask,
            commit_mask=commit,
            row_start=start,  # contiguous rows: dynamic_update_slice fast path
        )
        h, nc = apply_model(self.cfg, params, h, ctx, cache=cache)
        nc["len"] = start + jnp.asarray(n_commit, jnp.int32)
        return logits_from_hidden(self.cfg, params, h), nc

    def decode_step(self, params, cache, tokens, S_max):
        """tokens [B,1] -> (logits [B,1,V], cache')."""
        return self.chain_forward(params, cache, tokens, 1, S_max)

    # ---- misc ----------------------------------------------------------------
    @property
    def uses_chain_spec(self) -> bool:
        return self.cfg.sub_quadratic  # SSM/hybrid: tree spec inapplicable

    def needs_enc(self) -> bool:
        return any("cross" in unit for unit, _ in build_plan(self.cfg))


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
