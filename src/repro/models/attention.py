"""GQA/MQA attention: full-sequence (train/prefill), cached (decode/spec-tree),
and cross-attention against stub encoder states.

Cached mode takes an explicit ``[B, n, S_max]`` attention mask — this is the
paper's *non-square tree mask* (§3.1 "Non-square mask support"): the n query
rows are draft leaves / verification nodes attending the prefix cache plus
their tree ancestors.  All cache writes are masked one-hot scatters (never
dynamic-slice on the sharded sequence dim), so the sequence-sharded KV cache
("kv_seq" -> "model") updates without collectives; the softmax over the
sharded KV axis is XLA's distributed reduction — the mesh-scale analogue of
the paper's split-KV single-kernel combine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.flags import get_flags
from repro.models.common import apply_rope, dense_init, zeros_init
from repro.sharding import constrain


def init_attention(cfg, key, *, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), ("embed", "heads", "head_dim"), dt),
        "wk": dense_init(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": dense_init(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": dense_init(ks[3], (hq, hd, d), ("heads", "head_dim", "embed"), dt, scale=(hq * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((hq, hd), ("heads", "head_dim"), dt)
        p["bk"] = zeros_init((hkv, hd), ("kv_heads", "head_dim"), dt)
        p["bv"] = zeros_init((hkv, hd), ("kv_heads", "head_dim"), dt)
    return p


def _project_qkv(cfg, p, x, positions, *, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].value)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].value)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].value)
    if "bq" in p:
        q = q + p["bq"].value
        k = k + p["bk"].value
        v = v + p["bv"].value
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _grouped_scores(q, k):
    """q [B,n,Hq,hd], k [B,S,Hkv,hd] -> scores [B,Hkv,G,n,S] (GQA grouping)."""
    B, n, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(B, n, hkv, g, hd)
    return jnp.einsum("bnkgh,bskh->bkgns", qg, k) / jnp.sqrt(hd).astype(jnp.float32)


def _attend(q, k, v, mask):
    """Masked softmax attention. mask broadcastable to [B,Hkv,G,n,S]."""
    scores = _grouped_scores(q, k).astype(jnp.float32)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # guard fully-masked rows (padded queries)
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bkgns,bskh->bnkgh", probs.astype(v.dtype), v)
    B, n, hkv, g, hd = out.shape
    return out.reshape(B, n, hkv * g, hd)


def attention_full(cfg, p, x, positions, *, enc=None):
    """Full-sequence attention (train / prefill), q-chunked over the sequence.

    Returns (out [B,S,d], (k, v) computed K/V for cache population).
    ``enc`` -> cross-attention (no causal mask, no rope, K/V from enc).
    """
    flags = get_flags()
    B, S, _ = x.shape
    if enc is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].value)
        k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].value)
        v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].value)
        mask = jnp.ones((1, 1, 1, 1, 1), bool)
        out = _attend(q, k, v, mask)
        out = jnp.einsum("bnhk,hkd->bnd", out, p["wo"].value)
        return constrain(out, "batch", "seq", "act_embed"), (k, v)

    q, k, v = _project_qkv(cfg, p, x, positions)
    if flags.seq_shard_acts and flags.attn_heads_tp:
        # Megatron-SP: residuals stay seq-sharded OUTSIDE the block, but the
        # attention itself computes head-parallel — k/v all-gather once per
        # layer instead of psum-ing every q-chunk's seq-sharded scores.
        q = constrain(q, "batch", "seq", "heads", None)
        k = constrain(k, "batch", "seq", "kv_heads", None)
        v = constrain(v, "batch", "seq", "kv_heads", None)
    elif flags.seq_shard_acts:
        # sequence parallelism: K/V shard on seq over "model" (the layout the
        # cache keeps); scores per q-chunk then stay seq-sharded too.
        q = constrain(q, "batch", "act_seq", None, None)
        k = constrain(k, "batch", "kv_seq", None, None)
        v = constrain(v, "batch", "kv_seq", None, None)
    else:
        q = constrain(q, "batch", "seq", "heads", "head_dim")
        k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
        v = constrain(v, "batch", "seq", "kv_heads", "head_dim")

    chunk = min(flags.attn_chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk
    pos_k = positions  # [B,S]

    def one_chunk(ci):
        qc = jax.lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=1)
        pos_q = jax.lax.dynamic_slice_in_dim(positions, ci * chunk, chunk, axis=1)
        m = pos_k[:, None, :] <= pos_q[:, :, None]  # causal [B,c,S]
        if cfg.sliding_window:
            m &= pos_k[:, None, :] > (pos_q[:, :, None] - cfg.sliding_window)
        return _attend(qc, k, v, m[:, None, None, :, :])

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        # checkpoint each q-chunk: backward recomputes the chunk's mask and
        # probabilities instead of saving O(S^2/nc) residuals per chunk —
        # the memory-side half of flash attention, in pure XLA.
        outs = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, q.shape[2], q.shape[3])
    out = jnp.einsum("bnhk,hkd->bnd", out, p["wo"].value)
    return constrain(out, "batch", "seq", "act_embed"), (k, v)


def update_rows_contiguous(cache, rows, start):
    """Write ``rows [B,n,...]`` into ``cache [B,S,...]`` at [start, start+n).

    Decode/chain fast path.  Implemented as n per-row iota==row selects, NOT
    dynamic_update_slice: the cache is sequence-sharded over "model", and a
    DUS at a dynamic offset forces GSPMD into involuntary full
    rematerialization (replicate + re-partition), while the select compare is
    shard-local — one read + one write of the cache per row, no collectives.
    (n is the decode/chain chunk, <= 8; the general tree path uses
    scatter_rows below.)
    """
    start = jnp.asarray(start, jnp.int32)
    S = cache.shape[1]
    iota = jnp.arange(S, dtype=jnp.int32)
    n = rows.shape[1]
    bshape = (1, S) + (1,) * (cache.ndim - 2)
    for i in range(n):
        m = (iota == start + i).reshape(bshape)
        row = rows[:, i : i + 1].astype(cache.dtype)  # [B,1,...] broadcasts over S
        cache = jnp.where(m, row, cache)
    return cache


def scatter_rows(cache, rows, row_idx, row_mask=None):
    """Write ``rows [B,n,...]`` into ``cache [B,S,...]`` at ``row_idx [B,n]``.

    One-hot masked scatter: O(S*n) work, no re-layout of the sequence-sharded
    cache, duplicate/-1 indices are dropped via the mask.  This is the
    in-forward KV *write* path only — per-round cache reorganization
    (verify compaction, re-root moves) goes through ``core/kv.apply_moves``
    and the O(moved-rows) kernels in ``kernels/kv_moves.py`` instead.
    """
    B, S = cache.shape[:2]
    n = rows.shape[1]
    valid = row_idx >= 0
    if row_mask is not None:
        valid &= row_mask
    onehot = (row_idx[:, :, None] == jnp.arange(S)[None, None, :]) & valid[:, :, None]
    oh = onehot.astype(cache.dtype)  # [B,n,S]
    flat_r = rows.reshape(B, n, -1)
    flat_c = cache.reshape(B, S, -1)
    upd = jnp.einsum("bns,bnf->bsf", oh, flat_r)
    keep = 1.0 - jnp.einsum("bns->bs", oh).clip(0, 1)
    out = flat_c * keep[..., None].astype(cache.dtype) + upd
    return out.reshape(cache.shape)


def gather_rows(cache, row_idx):
    """Gather rows [B,n,...] from cache [B,S,...]; row_idx -1 -> zeros."""
    B, S = cache.shape[:2]
    n = row_idx.shape[1]
    onehot = (row_idx[:, :, None] == jnp.arange(S)[None, None, :]).astype(cache.dtype)
    flat_c = cache.reshape(B, S, -1)
    out = jnp.einsum("bns,bsf->bnf", onehot, flat_c)
    return out.reshape((B, n) + cache.shape[2:])


def attention_cached(cfg, p, x, cache_k, cache_v, row_idx, positions, attn_mask, *,
                     enc_kv=None, row_start=None):
    """Cached attention for decode / spec-tree forward.

    x: [B, n, d] new tokens; their K/V are written at ``row_idx`` [B, n]
    (absolute cache rows, -1 = skip).  ``attn_mask`` [B, n, S_max] is the
    non-square tree mask (True = attend).  Returns (out, new_k, new_v).
    For cross blocks, pass ``enc_kv=(k, v)`` and attn_mask=None.
    ``row_start``: scalar fast path — rows are [start, start+n) for every
    batch element (decode/chain), written via dynamic_update_slice.
    """
    flags = get_flags()
    if enc_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].value)
        out = _attend(q, enc_kv[0], enc_kv[1], jnp.ones((1, 1, 1, 1, 1), bool))
        out = jnp.einsum("bnhk,hkd->bnd", out, p["wo"].value)
        return out, None, None

    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    if row_start is not None:  # contiguous decode/chain rows: cheap in-place
        ck = update_rows_contiguous(cache_k, k_new, row_start)
        cv = update_rows_contiguous(cache_v, v_new, row_start)
    else:
        ck = scatter_rows(cache_k, k_new, row_idx)
        cv = scatter_rows(cache_v, v_new, row_idx)
    ck = constrain(ck, "cache_batch", "kv_seq", None, None)
    cv = constrain(cv, "cache_batch", "kv_seq", None, None)

    if flags.use_pallas_attention:
        from repro.kernels import ops as kops

        out = kops.tree_attention(q, ck, cv, attn_mask, interpret=flags.pallas_interpret)
    else:
        out = _attend(q, ck, cv, attn_mask[:, None, None, :, :])
    out = jnp.einsum("bnhk,hkd->bnd", out, p["wo"].value)
    return out, ck, cv
