from repro.models.api import Model, make_model

__all__ = ["Model", "make_model"]
