"""Generic decoder assembly: block plans, scan-over-units, caches.

Every architecture reduces to a *plan*: a list of groups, each group a
repeating unit of block kinds scanned ``n_reps`` times.

  dense arch        -> [ (("dense",), n_layers) ]
  mixtral           -> [ (("moe",), 56) ]
  deepseek-moe      -> [ (("dense",), 1), (("moe",), 27) ]        (first_k_dense)
  zamba2            -> [ (("mamba2",)*6 + ("shared",), 9) ]       (shared weights)
  llama-3.2-vision  -> [ (("dense",)*4 + ("cross",), 20) ]
  rwkv6             -> [ (("rwkv6",), 32) ]

Two modes:
  "full"   — whole-sequence causal (train; prefill when make_cache=S_max)
  "cached" — n new tokens against an existing cache with explicit non-square
             attention masks (decode / spec-tree / chain verification)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.flags import get_flags
from repro.models import mamba2 as m2
from repro.models import mla as mla_mod
from repro.models import rwkv6 as rk
from repro.models.attention import attention_cached, attention_full, init_attention
from repro.models.common import dense_init, ones_init, rms_norm
from repro.sharding import Param, add_leading_axis, constrain


# -----------------------------------------------------------------------------
# Plans
# -----------------------------------------------------------------------------


def build_plan(cfg):
    """Returns list of (unit_def: tuple[str], n_reps: int)."""
    plan = []
    first_k = getattr(cfg, "first_k_dense", 0)
    n_main = cfg.n_layers - first_k
    if first_k:
        plan.append((("dense",), first_k))
    if cfg.shared_attn_every:
        k = cfg.shared_attn_every
        assert n_main % k == 0, (cfg.name, n_main, k)
        plan.append((tuple(cfg.block_pattern) * k + ("shared",), n_main // k))
    else:
        pat = tuple(cfg.block_pattern)
        assert n_main % len(pat) == 0, (cfg.name, n_main, pat)
        plan.append((pat, n_main // len(pat)))
    return plan


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded to every block (closure, not scanned)."""

    mode: str  # "full" | "cached"
    make_cache: int = 0  # S_max when prefill should emit a cache
    positions: Any = None  # [B, n] absolute rope positions
    row_idx: Any = None  # [B, n] cache rows for new K/V (-1 = skip)
    attn_mask: Any = None  # [B, n, S_max] non-square mask (cached mode)
    enc: Any = None  # [B, n_enc, d] stub encoder states (cross blocks)
    commit_mask: Any = None  # [B, n] chain-mode state commit mask
    x0: Any = None  # original embeddings (zamba shared block input)
    row_start: Any = None  # scalar: rows are [start, start+n) for ALL batch
    #   elements (decode/chain path) -> cache writes use dynamic_update_slice
    #   instead of the onehot scatter (§Perf: kills the full-cache rewrite)


# -----------------------------------------------------------------------------
# Block init
# -----------------------------------------------------------------------------


def _init_mlp(cfg, key):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wg": dense_init(ks[0], (d, ff), ("embed", "ff"), dt),
        "wu": dense_init(ks[1], (d, ff), ("embed", "ff"), dt),
        "wd": dense_init(ks[2], (ff, d), ("ff", "embed"), dt),
    }


def init_block(cfg, kind, key):
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("dense", "moe", "cross"):
        if cfg.attn_kind == "mla" and kind != "cross":
            attn = mla_mod.init_mla(cfg, k1)
        else:
            attn = init_attention(cfg, k1, cross=(kind == "cross"))
        if kind == "moe":
            from repro.models.moe import init_moe

            mlp = init_moe(cfg, k2)
        else:
            mlp = _init_mlp(cfg, k2)
        return {
            "ln1": ones_init((d,), ("act_embed",), dt),
            "attn": attn,
            "ln2": ones_init((d,), ("act_embed",), dt),
            "mlp": mlp,
        }
    if kind == "mamba2":
        return {"ln": ones_init((d,), ("act_embed",), dt), "mamba": m2.init_mamba2(cfg, k1)}
    if kind == "rwkv6":
        return {
            "ln1": ones_init((d,), ("act_embed",), dt),
            "tm": rk.init_rwkv6(cfg, k1),
            "ln2": ones_init((d,), ("act_embed",), dt),
        }
    if kind == "shared":
        # per-invocation input projection over concat(h, x0); weights of the
        # inner attn+mlp are SHARED across invocations (stored model-level).
        return {"in_w": dense_init(k1, (2 * d, d), ("embed", "embed"), dt)}
    raise ValueError(kind)


def init_shared_attn(cfg, key):
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": ones_init((d,), ("act_embed",), dt),
        "attn": init_attention(cfg, k1),
        "ln2": ones_init((d,), ("act_embed",), dt),
        "mlp": _init_mlp(cfg, k2),
    }


# -----------------------------------------------------------------------------
# Block caches
# -----------------------------------------------------------------------------


def init_block_cache(cfg, kind, B, S_max, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    if kind in ("dense", "moe"):
        if cfg.attn_kind == "mla":
            return {
                "ckv": jnp.zeros((B, S_max, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((B, S_max, cfg.rope_head_dim), dtype),
            }
        return {
            "k": jnp.zeros((B, S_max, hkv, hd), dtype),
            "v": jnp.zeros((B, S_max, hkv, hd), dtype),
        }
    if kind == "cross":
        return {
            "ek": jnp.zeros((B, cfg.n_enc_tokens, hkv, hd), dtype),
            "ev": jnp.zeros((B, cfg.n_enc_tokens, hkv, hd), dtype),
        }
    if kind == "mamba2":
        return m2.init_mamba_cache(cfg, B, dtype)
    if kind == "rwkv6":
        return rk.init_rwkv_cache(cfg, B, dtype)
    if kind == "shared":
        return {
            "k": jnp.zeros((B, S_max, hkv, hd), dtype),
            "v": jnp.zeros((B, S_max, hkv, hd), dtype),
        }
    raise ValueError(kind)


# -----------------------------------------------------------------------------
# Block apply
# -----------------------------------------------------------------------------


def _mlp_apply(cfg, p, x):
    flags = get_flags()
    if flags.use_pallas_swiglu:
        from repro.kernels import ops as kops

        B, S, d = x.shape
        out = kops.fused_swiglu(
            x.reshape(B * S, d), p["wg"].value, p["wu"].value, interpret=flags.pallas_interpret
        )
        return (out @ p["wd"].value).reshape(B, S, d)
    g = x @ p["wg"].value
    u = x @ p["wu"].value
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "seq", "ff")
    return h @ p["wd"].value


def _attn_dispatch(cfg, p, h, ctx: Ctx, cache, kind):
    """Run the attention sub-block in the right mode; returns (out, new_cache)."""
    if kind == "cross":
        if ctx.mode == "full":
            out, (ek, ev) = attention_full(cfg, p, h, None, enc=ctx.enc)
            nc = {"ek": ek, "ev": ev} if ctx.make_cache else None
            return out, nc
        out, _, _ = attention_cached(
            cfg, p, h, None, None, None, None, None, enc_kv=(cache["ek"], cache["ev"])
        )
        return out, dict(cache)

    if cfg.attn_kind == "mla":
        if ctx.mode == "full":
            out, (ckv, krope) = mla_mod.mla_full(cfg, p, h, ctx.positions)
            nc = None
            if ctx.make_cache:
                pad = ctx.make_cache - ckv.shape[1]
                nc = {
                    "ckv": constrain(jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                                     "cache_batch", "kv_seq", None),
                    "krope": constrain(jnp.pad(krope, ((0, 0), (0, pad), (0, 0))),
                                       "cache_batch", "kv_seq", None),
                }
            return out, nc
        out, ckv, krope = mla_mod.mla_cached(
            cfg, p, h, cache["ckv"], cache["krope"], ctx.row_idx, ctx.positions,
            ctx.attn_mask, row_start=ctx.row_start
        )
        return out, {"ckv": ckv, "krope": krope}

    if ctx.mode == "full":
        out, (k, v) = attention_full(cfg, p, h, ctx.positions)
        nc = None
        if ctx.make_cache:
            pad = ctx.make_cache - k.shape[1]
            nc = {
                "k": constrain(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                               "cache_batch", "kv_seq", None, None),
                "v": constrain(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                               "cache_batch", "kv_seq", None, None),
            }
        return out, nc
    out, ck, cv = attention_cached(
        cfg, p, h, cache["k"], cache["v"], ctx.row_idx, ctx.positions, ctx.attn_mask,
        row_start=ctx.row_start,
    )
    return out, {"k": ck, "v": cv}


def apply_block(cfg, kind, p, h, ctx: Ctx, cache, shared_p):
    if kind in ("dense", "moe", "cross"):
        a, new_cache = _attn_dispatch(cfg, p["attn"], rms_norm(h, p["ln1"].value, cfg.norm_eps), ctx, cache, kind)
        h = h + a
        hn = rms_norm(h, p["ln2"].value, cfg.norm_eps)
        if kind == "moe":
            from repro.models.moe import moe_apply

            h = h + moe_apply(cfg, p["mlp"], hn)
        else:
            h = h + _mlp_apply(cfg, p["mlp"], hn)
        return h, new_cache
    if kind == "mamba2":
        out, new_cache = m2.mamba2_apply(
            cfg, p["mamba"], rms_norm(h, p["ln"].value, cfg.norm_eps), cache, ctx.commit_mask
        )
        if ctx.mode == "full" and not ctx.make_cache:
            new_cache = None
        return h + out, new_cache
    if kind == "rwkv6":
        tm_cache = None if cache is None else {"sx_tm": cache["sx_tm"], "wkv": cache["wkv"]}
        cm_cache = None if cache is None else {"sx_cm": cache["sx_cm"]}
        out, nc_tm = rk.rwkv6_time_mix(cfg, p["tm"], rms_norm(h, p["ln1"].value, cfg.norm_eps), tm_cache, ctx.commit_mask)
        h = h + out
        out, nc_cm = rk.rwkv6_channel_mix(cfg, p["tm"], rms_norm(h, p["ln2"].value, cfg.norm_eps), cm_cache, ctx.commit_mask)
        h = h + out
        new_cache = {**nc_tm, **nc_cm}
        if ctx.mode == "full" and not ctx.make_cache:
            new_cache = None
        return h, new_cache
    if kind == "shared":
        # zamba2: weight-shared attn+mlp block on concat(h, x0)
        inp = jnp.concatenate([h, ctx.x0], axis=-1) @ p["in_w"].value
        a, new_cache = _attn_dispatch(
            cfg, shared_p["attn"], rms_norm(inp, shared_p["ln1"].value, cfg.norm_eps), ctx, cache, "dense"
        )
        inp = inp + a
        hn = rms_norm(inp, shared_p["ln2"].value, cfg.norm_eps)
        inp = inp + _mlp_apply(cfg, shared_p["mlp"], hn)
        return h + inp, new_cache
    raise ValueError(kind)


# -----------------------------------------------------------------------------
# Model init / apply
# -----------------------------------------------------------------------------


def init_model(cfg, key):
    plan = build_plan(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, len(plan) + 3)
    params = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dt, scale=1.0),
        "final_norm": ones_init((cfg.d_model,), ("act_embed",), dt),
        "lm_head": dense_init(keys[1], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt),
        "groups": [],
        "shared_attn": None,
    }
    if any("shared" in unit for unit, _ in plan):
        params["shared_attn"] = init_shared_attn(cfg, keys[2])
    for gi, (unit_def, n_reps) in enumerate(plan):
        gkey = keys[3 + gi]

        def init_unit(k):
            bkeys = jax.random.split(k, len(unit_def))
            return tuple(init_block(cfg, kind, bk) for kind, bk in zip(unit_def, bkeys))

        stacked = jax.vmap(init_unit)(jax.random.split(gkey, n_reps))
        params["groups"].append(add_leading_axis(stacked, "unit"))
    return params


def init_cache(cfg, B, S_max, dtype):
    plan = build_plan(cfg)
    groups = []
    for unit_def, n_reps in plan:
        unit = tuple(init_block_cache(cfg, kind, B, S_max, dtype) for kind in unit_def)
        stacked = jax.tree.map(lambda x: jnp.zeros((n_reps,) + x.shape, x.dtype), unit)
        groups.append(stacked)
    return {"len": jnp.zeros((), jnp.int32), "groups": groups}


def apply_model(cfg, params, h, ctx: Ctx, cache=None):
    """h: [B, n, d] embedded inputs. Returns (hidden [B,n,d], new_cache)."""
    flags = get_flags()
    plan = build_plan(cfg)
    ctx.x0 = h if any("shared" in u for u, _ in plan) else None
    shared_p = params["shared_attn"]
    new_groups = []

    for gi, (unit_def, n_reps) in enumerate(plan):
        stacked = params["groups"][gi]
        cache_g = cache["groups"][gi] if cache is not None else None
        emit_cache = ctx.mode == "cached" or ctx.make_cache

        def unit_fn(h_carry, xs):
            up, uc = xs
            new_uc = []
            for bi, kind in enumerate(unit_def):
                bc = None if uc is None else uc[bi]
                h_carry, nc = apply_block(cfg, kind, up[bi], h_carry, ctx, bc, shared_p)
                new_uc.append(nc)
            return h_carry, tuple(new_uc) if emit_cache else None

        if flags.seq_shard_acts:
            # sequence parallelism: the residual stream carried between units
            # (and saved by remat) shards over "model", bounding per-device
            # activation memory at production sequence lengths.
            inner_fn = unit_fn

            def unit_fn(h_carry, xs):  # noqa: F811
                h_carry = constrain(h_carry, "batch", "act_seq", None)
                h_out, ys = inner_fn(h_carry, xs)
                return constrain(h_out, "batch", "act_seq", None), ys

        if flags.remat == "full":
            unit_fn = jax.checkpoint(unit_fn)

        if flags.scan_layers and n_reps > 1:
            h, ys = jax.lax.scan(unit_fn, h, (stacked, cache_g))
            new_groups.append(ys)
        else:
            ys = []
            for r in range(n_reps):
                up = jax.tree.map(
                    lambda p, _r=r: Param(p.value[_r], p.axes[1:]),
                    stacked,
                    is_leaf=lambda x: isinstance(x, Param),
                )
                uc = None if cache_g is None else jax.tree.map(lambda x, _r=r: x[_r], cache_g)
                h, nc = unit_fn(h, (up, uc))
                ys.append(nc)
            if emit_cache:
                new_groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *ys))
            else:
                new_groups.append(None)

    h = rms_norm(h, params["final_norm"].value, cfg.norm_eps)
    if cache is not None or ctx.make_cache:
        return h, {"len": None, "groups": new_groups}  # len managed by caller
    return h, None


def axes_tree(stacked):
    return jax.tree.map(lambda p: p.axes, stacked, is_leaf=lambda x: isinstance(x, Param))


def logits_from_hidden(cfg, params, h):
    logits = h @ params["lm_head"].value
    return constrain(logits, "batch", "seq", "vocab")


def embed_tokens(cfg, params, tokens):
    emb = params["embed"].value[tokens]
    return constrain(emb, "batch", "seq", "act_embed")
