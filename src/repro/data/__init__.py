from repro.data.pipeline import (
    DataConfig,
    SyntheticLMDataset,
    TraceRequest,
    make_request_stream,
    make_request_trace,
    sharded_batches,
)

__all__ = [
    "DataConfig",
    "SyntheticLMDataset",
    "TraceRequest",
    "make_request_stream",
    "make_request_trace",
    "sharded_batches",
]
