from repro.data.pipeline import (
    DataConfig,
    SyntheticLMDataset,
    make_request_stream,
    sharded_batches,
)

__all__ = ["DataConfig", "SyntheticLMDataset", "make_request_stream", "sharded_batches"]
