"""Deterministic synthetic data pipeline (train + serve sides).

Training: a seeded Markov-chain token stream packed into fixed-length
sequences — deterministic given (seed, step), so a restarted job resumes on
exactly the bytes it would have seen (the property the checkpoint tests
assert).  The chain has low entropy (peaked transitions), which also makes it
the right stimulus for speculative-decoding benchmarks: a smaller draft model
trained/behaving on the same process produces realistic acceptance rates.

Serving: ``make_request_stream`` yields deterministic prompt batches shaped
like the paper's single-request / small-batch workloads.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 4  # Markov out-degree: lower = peakier = more predictable


class SyntheticLMDataset:
    """Seeded Markov LM stream; ``batch(step)`` is a pure function of step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, B = cfg.vocab_size, cfg.branch
        # per-state successor table + peaked probabilities
        self._succ = rng.integers(0, V, size=(V, B), dtype=np.int32)
        p = np.geomspace(1.0, 0.05, B)
        self._probs = p / p.sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """-> {"tokens": [B, S+1] int32} (inputs = [:, :-1], labels = [:, 1:])."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        out = np.empty((B, S + 1), np.int32)
        cur = rng.integers(0, cfg.vocab_size, size=B, dtype=np.int32)
        out[:, 0] = cur
        choices = rng.choice(cfg.branch, size=(B, S), p=self._probs)
        for t in range(S):
            cur = self._succ[cur, choices[:, t]]
            out[:, t + 1] = cur
        return {"tokens": out}


def sharded_batches(ds: SyntheticLMDataset, mesh, start_step: int = 0) -> Iterator[dict]:
    """Yield device-sharded (batch-over-('pod','data')) token batches."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sh = NamedSharding(mesh, P(axes if axes else None, None))
    step = start_step
    while True:
        host = ds.batch(step)
        yield {
            "step": step,
            "tokens": jax.device_put(host["tokens"], sh),
        }
        step += 1


def _draw_prompt_len(rng, prompt_len) -> int:
    """int -> fixed; (lo, hi) -> uniform over 4-token buckets in [lo, hi].

    Bucketing keeps the set of distinct prompt shapes small: the serving
    runtime prefills each admitted request solo, and every distinct length is
    one XLA compile of the prefill program."""
    if isinstance(prompt_len, int):
        return prompt_len
    lo, hi = prompt_len
    buckets = list(range(lo, hi + 1, 4)) or [lo]
    return int(buckets[rng.integers(0, len(buckets))])


def make_request_stream(vocab_size: int, prompt_len, batch: int, n_requests: int,
                        seed: int = 0) -> Iterator[np.ndarray]:
    """Deterministic serving prompts [batch, P] int32.

    ``prompt_len``: an int for fixed-shape prompts (the original behaviour),
    or a (lo, hi) tuple for variable lengths drawn per request."""
    rng = np.random.default_rng(seed)
    for _ in range(n_requests):
        P = _draw_prompt_len(rng, prompt_len)
        yield rng.integers(0, vocab_size, size=(batch, P), dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One entry of a serving arrival trace."""

    rid: int
    arrival_s: float
    prompt: np.ndarray  # i32[P]
    max_new: int = 32


def make_request_trace(vocab_size: int, n_requests: int, *, rate_rps: float = 2.0,
                       prompt_len=(8, 24), max_new: int = 32,
                       seed: int = 0) -> list[TraceRequest]:
    """Seeded Poisson arrival trace with variable prompt lengths.

    Inter-arrival gaps are exponential with mean ``1 / rate_rps`` (a Poisson
    process at ``rate_rps`` requests/s), prompt lengths are drawn per request
    (see ``_draw_prompt_len``); both deterministic given ``seed``.  This is
    the realistic-traffic stimulus for the continuous-batching runtime:
    bursts queue up, lulls drain slots."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for i in range(n_requests):
        if i > 0:
            t += float(rng.exponential(1.0 / rate_rps))
        P = _draw_prompt_len(rng, prompt_len)
        prompt = rng.integers(0, vocab_size, size=(P,), dtype=np.int32)
        trace.append(TraceRequest(rid=i, arrival_s=t, prompt=prompt, max_new=max_new))
    return trace
