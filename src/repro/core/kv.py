"""KV-cache reorganization (paper §3.2): apply re-root MovePlans and
verification compaction to model caches while preserving the
``[prefix | tree]`` layout invariant.

All moves are gather-then-scatter on the functional cache (sources are read
from the pre-move cache in full before any write), so overlapping src/dst
rows are safe by construction.  Row ops touch only attention-cache leaves
("k"/"v"/"ckv"/"krope"); SSM states and cross-encoder KV are structurally
exempt (chain mode / static).

Speculative fork / rollback contract (async rounds): because every operation
here is functional, a cache "snapshot" is just a retained reference — zero
copies.  The async lookahead (``EngineSession.draft_next_tree``) keeps the
pre-reroot (tree, dcache) pair alive and re-roots through a NON-donating jit;
if the lookahead seed is rejected, ``reconcile`` simply re-applies the move
plan to the retained reference (exact rollback), and if it commits, dropping
the reference frees the fork.  Any new cache op must preserve this: never
mutate a cache in place, and never donate a buffer the caller may still hold.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import gather_rows, scatter_rows

ROW_KEYS = ("k", "v", "ckv", "krope")


def map_row_leaves(cache, fn):
    """Apply ``fn`` to every row-indexed cache leaf [U, B, S, ...]."""

    def rec(x):
        if isinstance(x, dict):
            return {k: (fn(v) if k in ROW_KEYS else rec(v)) for k, v in x.items()}
        if isinstance(x, (list, tuple)):
            return type(x)(rec(v) for v in x)
        return x

    return {"len": cache["len"], "groups": rec(cache["groups"])}


def apply_moves(cache, src, dst, mask):
    """src/dst/mask: [B, M] row move plan (vmapped over the layer stack)."""

    def one_layer(arr):  # arr: [B, S, ...]
        rows = gather_rows(arr, jnp.maximum(src, 0))
        return scatter_rows(arr, rows, dst, mask & (src >= 0))

    def per_leaf(arr):  # [U, B, S, ...]
        return jax.vmap(one_layer)(arr)

    return map_row_leaves(cache, per_leaf)


def set_length(cache, new_len):
    return {**cache, "len": jnp.asarray(new_len, jnp.int32)}


# -----------------------------------------------------------------------------
# per-slot (batch-row) lifecycle — continuous-batching serving (serving/)
# -----------------------------------------------------------------------------
# A "slot" is one batch row of a long-lived serving cache.  Requests are
# admitted into free slots (install_slot: copy a fresh single-request prefill
# cache into the row) and retired (zero_slot: physically clear the row so no
# KV can leak into the slot's next occupant).  Both touch EVERY array leaf —
# attention K/V rows and recurrent states alike — and leave the global "len"
# scalar alone: per-slot length bookkeeping lives in the per-row tree
# (tree.plen); spec_forward masks are explicit and never read "len".


def install_slot(cache, src, slot):
    """Copy batch row 0 of single-request cache ``src`` into batch row
    ``slot`` of ``cache``.  ``slot`` may be traced (one jit for all slots)."""

    def copy(big, one):
        return big.at[:, slot].set(one[:, 0].astype(big.dtype))

    return {"len": cache["len"], "groups": jax.tree.map(copy, cache["groups"], src["groups"])}


def zero_slot(cache, slot):
    """Zero batch row ``slot`` of every cache leaf (retired-slot hygiene:
    a recycled slot starts from provably clean state)."""

    def clear(x):
        return x.at[:, slot].set(jnp.zeros_like(x[:, 0]))

    return {"len": cache["len"], "groups": jax.tree.map(clear, cache["groups"])}
