"""KV-cache reorganization (paper §3.2): apply re-root MovePlans and
verification compaction to model caches while preserving the
``[prefix | tree]`` layout invariant.

All moves are gather-then-scatter on the functional cache (sources are read
from the pre-move cache in full before any write), so overlapping src/dst
rows are safe by construction.  Row ops touch only attention-cache leaves
("k"/"v"/"ckv"/"krope"); SSM states and cross-encoder KV are structurally
exempt (chain mode / static).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import gather_rows, scatter_rows

ROW_KEYS = ("k", "v", "ckv", "krope")


def map_row_leaves(cache, fn):
    """Apply ``fn`` to every row-indexed cache leaf [U, B, S, ...]."""

    def rec(x):
        if isinstance(x, dict):
            return {k: (fn(v) if k in ROW_KEYS else rec(v)) for k, v in x.items()}
        if isinstance(x, (list, tuple)):
            return type(x)(rec(v) for v in x)
        return x

    return {"len": cache["len"], "groups": rec(cache["groups"])}


def apply_moves(cache, src, dst, mask):
    """src/dst/mask: [B, M] row move plan (vmapped over the layer stack)."""

    def one_layer(arr):  # arr: [B, S, ...]
        rows = gather_rows(arr, jnp.maximum(src, 0))
        return scatter_rows(arr, rows, dst, mask & (src >= 0))

    def per_leaf(arr):  # [U, B, S, ...]
        return jax.vmap(one_layer)(arr)

    return map_row_leaves(cache, per_leaf)


def set_length(cache, new_len):
    return {**cache, "len": jnp.asarray(new_len, jnp.int32)}
