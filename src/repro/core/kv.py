"""KV-cache reorganization (paper §3.2): apply re-root MovePlans and
verification compaction to model caches while preserving the
``[prefix | tree]`` layout invariant.

All moves are gather-then-scatter on the functional cache (sources are read
from the pre-move cache in full before any write), so overlapping src/dst
rows are safe by construction.  Row ops touch only attention-cache leaves
("k"/"v"/"ckv"/"krope"); SSM states and cross-encoder KV are structurally
exempt (chain mode / static).

The row moves dispatch through ``repro.kernels.ops.kv_move_rows``: an
index-based reference path (gather/scatter of exactly the M plan rows), or —
under ``flags.use_pallas_kv_moves`` — the fused Pallas kernel that DMAs only
the moved rows, O(B·M·F) HBM traffic instead of the two dense O(B·S·F)
passes of the retired one-hot einsum formulation (docs/kernels.md).

Speculative fork / rollback contract (async rounds): because every operation
here is functional, a cache "snapshot" is just a retained reference — zero
copies.  The async lookahead (``EngineSession.draft_next_tree``) keeps the
pre-reroot (tree, dcache) pair alive and re-roots through a NON-donating jit;
if the lookahead seed is rejected, ``reconcile`` simply re-applies the move
plan to the retained reference (exact rollback), and if it commits, dropping
the reference frees the fork.  Any new cache op must preserve this: never
mutate a cache in place, and never donate a buffer the caller may still hold.
``apply_moves(..., donate=True)`` is the one sanctioned exception — it tells
the fused kernel it may alias output onto input, and is only legal inside a
jit that donates the cache argument (the caller provably holds no reference).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

ROW_KEYS = ("k", "v", "ckv", "krope")


def map_row_leaves(cache, fn):
    """Apply ``fn`` to every row-indexed cache leaf [U, B, S, ...]."""

    def rec(x):
        if isinstance(x, dict):
            return {k: (fn(v) if k in ROW_KEYS else rec(v)) for k, v in x.items()}
        if isinstance(x, (list, tuple)):
            return type(x)(rec(v) for v in x)
        return x

    return {"len": cache["len"], "groups": rec(cache["groups"])}


def apply_moves(cache, src, dst, mask, *, donate: bool = False):
    """src/dst/mask: [B, M] row move plan, applied to every row leaf.

    ``donate=True`` permits in-place movement (fused kernel aliasing) and is
    only legal when the wrapping jit donates the cache — see the module
    docstring's rollback contract.
    """

    def per_leaf(arr):  # [U, B, S, ...]
        return ops.kv_move_rows(arr, src, dst, mask, donate=donate)

    return map_row_leaves(cache, per_leaf)


def set_length(cache, new_len):
    return {**cache, "len": jnp.asarray(new_len, jnp.int32)}


# -----------------------------------------------------------------------------
# per-slot (batch-row) lifecycle — continuous-batching serving (serving/)
# -----------------------------------------------------------------------------
# A "slot" is one batch row of a long-lived serving cache.  Requests are
# admitted into free slots (install_slot: copy a fresh single-request prefill
# cache into the row) and retired (zero_slot: physically clear the row so no
# KV can leak into the slot's next occupant).  Both touch EVERY array leaf —
# attention K/V rows and recurrent states alike — and leave the global "len"
# scalar alone: per-slot length bookkeeping lives in the per-row tree
# (tree.plen); spec_forward masks are explicit and never read "len".
#
# Both run as ONE stacked update per call: under ``use_pallas_kv_moves`` a
# single ``slot_write_rows`` launch DMAs one row per leaf (zeroing uses an
# all-zeros donor cache), otherwise the XLA fallback below issues the
# per-leaf updates inside one jitted program.  Leaves that don't fit the
# kernel contract fall back per-call, so hybrid caches always work.


def _write_slot_rows(cache, donor, slot, fallback):
    """Shared install/zero body: write donor row 0 into ``slot`` of every
    groups leaf, fused when possible, else via ``fallback(big, one)``."""
    big_leaves, treedef = jax.tree.flatten(cache["groups"])
    one_leaves = jax.tree.leaves(donor["groups"])
    fused = ops.slot_write_rows(big_leaves, one_leaves, slot)
    if fused is not None:
        return {"len": cache["len"], "groups": jax.tree.unflatten(treedef, fused)}
    return {"len": cache["len"],
            "groups": jax.tree.map(fallback, cache["groups"], donor["groups"])}


def install_slot(cache, src, slot):
    """Copy batch row 0 of single-request cache ``src`` into batch row
    ``slot`` of ``cache``.  ``slot`` may be traced (one jit for all slots)."""

    def copy(big, one):
        return big.at[:, slot].set(one[:, 0].astype(big.dtype))

    return _write_slot_rows(cache, src, slot, copy)


def zero_slot(cache, slot):
    """Zero batch row ``slot`` of every cache leaf (retired-slot hygiene:
    a recycled slot starts from provably clean state)."""
    zeros = {"groups": jax.tree.map(
        lambda x: jnp.zeros((x.shape[0], 1) + x.shape[2:], x.dtype), cache["groups"])}

    def clear(x, _z):
        return x.at[:, slot].set(jnp.zeros_like(x[:, 0]))

    return _write_slot_rows(cache, zeros, slot, clear)
