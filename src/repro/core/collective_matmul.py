"""Collective matmul: the TPU-native analogue of the paper's fused
GEMM + all-reduce (§3.3, DESIGN.md §3).

The GPU kernel interleaves GEMM tiles with NCCL-LL stores so communication
rides inside the compute kernel.  On TPU the equivalent transformation is a
ring decomposition under shard_map: each step multiplies the locally-resident
activation shard against the weight shard and ``ppermute``s the activation to
the next neighbour, so per-step ICI transfer overlaps the next MXU step (the
XLA latency-hiding scheduler pipelines the permute with the dot).  Two
variants:

  rs_matmul  — reduce-scatter-style: y_partial computed per step, summed into
               the shard each device owns (GEMM + all-reduce fused; output
               row-sharded, exactly what the next layer wants under TP).
  ag_matmul  — all-gather-style: activation shards stream around the ring and
               accumulate into the full product (output replicated).

Used by the §Perf hillclimb through flags.collective_matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import shard_map


def matmul_allreduce(x, w, mesh, axis: str = "model"):
    """y = x @ w with w K-sharded over ``axis``; all-reduce fused via
    reduce-scatter + all-gather (the ring schedule XLA pipelines on ICI).

    x: [M, K] replicated activations; w: [K, N] sharded on K.
    """
    def body(x_loc, w_loc):
        part = jnp.einsum("mk,kn->mn", x_loc, w_loc)
        scat = jax.lax.psum_scatter(part, axis, scatter_dimension=1, tiled=True)
        return jax.lax.all_gather(scat, axis, axis=1, tiled=True)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None),
        check_vma=False,
    )(x, w)


def matmul_ag_pipelined(x, w, mesh, axis: str = "model"):
    """y = x @ w with x K-sharded; activation shards ride the ring while each
    local GEMM runs (collective-matmul proper: O(K/p) resident activations).
    """
    def body(x_loc, w_loc):
        # static axis extent from the mesh (jax.lax.axis_size is newer jax,
        # and the ring permutation below needs a Python int anyway)
        p = mesh.shape[axis]
        idx = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % p) for i in range(p)]
        kshard = w_loc.shape[0] // p

        def step(carry, i):
            x_cur, acc = carry
            src = (idx - i) % p  # which K-shard x_cur holds at step i
            wk = jax.lax.dynamic_slice_in_dim(w_loc, src * kshard, kshard, axis=0)
            acc = acc + jnp.einsum("mk,kn->mn", x_cur, wk)
            x_nxt = jax.lax.ppermute(x_cur, axis, perm)
            return (x_nxt, acc), None

        acc0 = jnp.zeros((x_loc.shape[0], w_loc.shape[1]), x_loc.dtype)
        (_, acc), _ = jax.lax.scan(step, (x_loc, acc0), jnp.arange(p))
        return acc

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(None, None)),
        out_specs=P(None, None),
        check_vma=False,
    )(x, w)
