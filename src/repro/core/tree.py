"""The draft tree: fixed-shape, jittable algebra for parallel tree generation
(paper §3.1) and consistent KV-cache management (§3.2).

Layout invariant (paper Fig. 5): cache rows [0, plen) hold the verified
tokens' KV — the *prefix cache* — with the tree ROOT's token at row plen-1;
rows [plen, ...) hold tree-node KV — the *tree cache* — allocated
monotonically and re-compacted at every re-root.

Node invariants:
  * node 0 is always the root (re-root compacts indices);
  * ``expanded`` ⟺ the node has been fed through the draft model, i.e. its
    KV exists at ``kv_row`` AND its children have been proposed;
  * every strict ancestor of any node is expanded (children only appear at
    expansion), so any unexpanded node can be expanded directly;
  * ``weight`` = cumulative log-prob root→node (root 0.0), monotonically
    non-increasing along paths — hence a stable sort by weight is
    automatically ancestor-closed (the paper's max-likelihood subgraph).

All functions are single-request; the engine vmaps them over the request
batch.  Capacities (n_cap, w, c, bs) are static.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG = -1e30


class Tree(NamedTuple):
    tokens: jax.Array  # i32[N]
    parent: jax.Array  # i32[N], -1 for root
    logp: jax.Array  # f32[N]
    weight: jax.Array  # f32[N] cum logp from root
    depth: jax.Array  # i32[N], root=0
    valid: jax.Array  # bool[N]
    expanded: jax.Array  # bool[N]
    kv_row: jax.Array  # i32[N] absolute cache row of node KV (-1 missing)
    n_nodes: jax.Array  # i32 scalar
    plen: jax.Array  # i32 scalar, prefix length (root token at row plen-1)
    next_row: jax.Array  # i32 scalar, next free tree-cache row


class BatchPlan(NamedTuple):
    """Inputs for one target verification forward (paper Alg. 1 line 12)."""

    node_ids: jax.Array  # i32[bs] tree node per batch slot (slot 0 = root)
    tokens: jax.Array  # i32[bs]
    rows: jax.Array  # i32[bs] target cache rows (plen-1 + slot)
    positions: jax.Array  # i32[bs] rope positions
    mask: jax.Array  # bool[bs, S_max] target attention mask
    parent_pos: jax.Array  # i32[bs] batch slot of parent (-1 for root)
    valid: jax.Array  # bool[bs]


class MovePlan(NamedTuple):
    """KV row moves for re-root compaction (applied by core/kv.py)."""

    src: jax.Array  # i32[M]
    dst: jax.Array  # i32[M]
    mask: jax.Array  # bool[M]


class FillPlan(NamedTuple):
    """Accepted-but-never-expanded tokens whose prefix KV must be computed."""

    tokens: jax.Array  # i32[F]
    rows: jax.Array  # i32[F]
    positions: jax.Array  # i32[F]
    mask: jax.Array  # bool[F] (any() -> a draft fill forward is needed)


# -----------------------------------------------------------------------------
# construction
# -----------------------------------------------------------------------------


def init_tree(n_cap: int) -> Tree:
    z = jnp.zeros((n_cap,), jnp.int32)
    return Tree(
        tokens=z,
        parent=jnp.full((n_cap,), -1, jnp.int32),
        logp=jnp.zeros((n_cap,), jnp.float32),
        weight=jnp.full((n_cap,), NEG, jnp.float32),
        depth=z,
        valid=jnp.zeros((n_cap,), bool),
        expanded=jnp.zeros((n_cap,), bool),
        kv_row=jnp.full((n_cap,), -1, jnp.int32),
        n_nodes=jnp.zeros((), jnp.int32),
        plen=jnp.zeros((), jnp.int32),
        next_row=jnp.zeros((), jnp.int32),
    )


def seed_root(tree: Tree, token, plen, root_logits, c: int) -> Tree:
    """Root = last verified token (KV at row plen-1, produced by prefill);
    children proposed from the prefill logits — root starts expanded."""
    n_cap = tree.tokens.shape[0]
    lp = jax.nn.log_softmax(root_logits.astype(jnp.float32))
    top_lp, top_tok = jax.lax.top_k(lp, c)
    t = tree
    t = t._replace(
        tokens=t.tokens.at[0].set(token),
        parent=t.parent.at[0].set(-1),
        logp=t.logp.at[0].set(0.0),
        weight=t.weight.at[0].set(0.0),
        depth=t.depth.at[0].set(0),
        valid=t.valid.at[0].set(True),
        expanded=t.expanded.at[0].set(True),
        kv_row=t.kv_row.at[0].set(plen - 1),
        n_nodes=jnp.asarray(1 + c, jnp.int32),
        plen=jnp.asarray(plen, jnp.int32),
        next_row=jnp.asarray(plen, jnp.int32),
    )
    idx = 1 + jnp.arange(c)
    t = t._replace(
        tokens=t.tokens.at[idx].set(top_tok),
        parent=t.parent.at[idx].set(0),
        logp=t.logp.at[idx].set(top_lp),
        weight=t.weight.at[idx].set(top_lp),
        depth=t.depth.at[idx].set(1),
        valid=t.valid.at[idx].set(idx < n_cap),
        expanded=t.expanded.at[idx].set(False),
        kv_row=t.kv_row.at[idx].set(-1),
    )
    return t


# -----------------------------------------------------------------------------
# per-slot lifecycle on a batched (stacked) tree — serving runtime
# -----------------------------------------------------------------------------
# The engine vmaps the single-request algebra above over a stacked Tree whose
# leaves carry a leading slot axis [B, ...].  Continuous batching admits and
# retires requests one slot at a time; these two helpers rewrite exactly one
# batch row without disturbing in-flight neighbors.


def seed_slot(tr: Tree, slot, token, plen, root_logits, c: int) -> Tree:
    """Re-seed batch row ``slot`` of a stacked Tree for a newly admitted
    request (root = last prompt token at prefix row ``plen - 1``).  ``slot``
    and ``plen`` may be traced, so one jit covers every slot and prompt
    length."""
    n_cap = tr.tokens.shape[1]
    fresh = seed_root(init_tree(n_cap), token, plen, root_logits, c)
    return jax.tree.map(lambda full, one: full.at[slot].set(one), tr, fresh)


def reset_slot(tr: Tree, slot) -> Tree:
    """Park batch row ``slot``: restore the empty init_tree state (no valid
    nodes), making the slot inert in expand/verify until its next admission."""
    n_cap = tr.tokens.shape[1]
    fresh = init_tree(n_cap)
    return jax.tree.map(lambda full, one: full.at[slot].set(one), tr, fresh)


# -----------------------------------------------------------------------------
# ancestors / masks
# -----------------------------------------------------------------------------


def ancestor_matrix(tree: Tree) -> jax.Array:
    """anc[i, j] = True iff j is an ancestor-or-self of i (valid nodes)."""
    n = tree.tokens.shape[0]

    def body(_, state):
        anc, cur = state
        anc = anc | (jax.nn.one_hot(cur, n, dtype=jnp.int32) > 0) & (cur >= 0)[:, None]
        cur = jnp.where(cur >= 0, tree.parent[jnp.maximum(cur, 0)], -1)
        return anc, cur

    anc0 = jnp.zeros((n, n), bool)
    cur0 = jnp.arange(n, dtype=jnp.int32)
    anc, _ = jax.lax.fori_loop(0, n, body, (anc0, cur0))
    return anc & tree.valid[None, :] & tree.valid[:, None]


def rows_mask(tree: Tree, ids, ids_valid, own_rows, S_max: int, window: int = 0):
    """Non-square attention mask [k, S_max] for draft nodes ``ids``:
    prefix rows [0, plen) + tree-ancestor rows + own row (self-attention).

    ``window``: sliding-window constraint applied to prefix rows (tree depths
    are far below any realistic window)."""
    k = ids.shape[0]
    cols = jnp.arange(S_max, dtype=jnp.int32)
    anc = ancestor_matrix(tree)[jnp.maximum(ids, 0)]  # [k, N]
    anc &= ids_valid[:, None]
    # map ancestor nodes -> their cache rows (root row plen-1 is in prefix,
    # already covered, but harmless to re-mark)
    row_of = tree.kv_row  # [N]
    has_kv = row_of >= 0
    onehot = (row_of[None, :, None] == cols[None, None, :]) & has_kv[None, :, None]
    m_tree = jnp.einsum("kn,xns->ks", anc.astype(jnp.int32), onehot.astype(jnp.int32)) > 0
    m_prefix = cols[None, :] < tree.plen
    if window:
        q_pos = tree.plen - 1 + tree.depth[jnp.maximum(ids, 0)]
        m_prefix &= cols[None, :] > (q_pos[:, None] - window)
    m_self = cols[None, :] == own_rows[:, None]
    return (m_prefix | m_tree | (m_self & ids_valid[:, None])) & ids_valid[:, None]


# -----------------------------------------------------------------------------
# expansion (paper Alg. 1 lines 3-4, §3.1 maximum-likelihood tree expansion)
# -----------------------------------------------------------------------------


def select_leaves(tree: Tree, w: int):
    """Top-w most probable unexpanded nodes (the priority-queue pop)."""
    score = jnp.where(tree.valid & ~tree.expanded, tree.weight, NEG)
    top, ids = jax.lax.top_k(score, w)
    return ids.astype(jnp.int32), top > NEG / 2


def leaf_inputs(tree: Tree, leaf_ids, leaf_valid, S_max: int, window: int = 0):
    """Model inputs for expanding ``leaf_ids``.

    Returns (tokens[w], rows[w], positions[w], mask[w,S_max], new_next_row).
    Root (node 0) writes its KV at prefix row plen-1; other leaves get fresh
    tree-cache rows.
    """
    w = leaf_ids.shape[0]
    # gate on leaf_valid: top_k pads short leaf sets with arbitrary ids, and a
    # padded id of 0 must NOT alias the root — it would claim row plen-1 and
    # the expansion forward would clobber the root's prefix KV with garbage
    is_root = (leaf_ids == 0) & leaf_valid
    non_root = leaf_valid & ~is_root
    rank = jnp.cumsum(non_root.astype(jnp.int32)) - 1
    rows = jnp.where(
        is_root,
        tree.plen - 1,
        jnp.where(non_root, tree.next_row + rank, -1),
    ).astype(jnp.int32)
    rows = jnp.where(rows < S_max, rows, -1)  # cache overflow -> skip
    new_next_row = tree.next_row + jnp.sum(non_root & (rows >= 0))
    tokens = jnp.where(leaf_valid, tree.tokens[jnp.maximum(leaf_ids, 0)], 0)
    positions = jnp.where(
        leaf_valid, tree.plen - 1 + tree.depth[jnp.maximum(leaf_ids, 0)], 0
    ).astype(jnp.int32)
    mask = rows_mask(tree, leaf_ids, leaf_valid & (rows >= 0), rows, S_max, window)
    return tokens, rows, positions, mask, new_next_row


def insert_children(tree: Tree, leaf_ids, leaf_valid, rows, child_tokens, child_logp) -> Tree:
    """Commit one expansion: mark leaves expanded (KV at ``rows``), append
    w*c children with cumulative weights.  Children beyond capacity drop."""
    n_cap = tree.tokens.shape[0]
    w, c = child_tokens.shape
    ok = leaf_valid & (rows >= 0)
    t = tree._replace(
        expanded=jnp.where(
            jnp.any(jnp.arange(n_cap)[None, :] == jnp.where(ok, leaf_ids, -2)[:, None], axis=0),
            True,
            tree.expanded,
        ),
        kv_row=scatter_i32(tree.kv_row, leaf_ids, rows, ok),
        next_row=tree.next_row + jnp.sum(ok & (leaf_ids != 0)),
    )
    # flatten children
    pl = jnp.repeat(jnp.where(ok, leaf_ids, 0), c)  # parent ids [w*c]
    pv = jnp.repeat(ok, c)
    ct = child_tokens.reshape(-1)
    cl = child_logp.reshape(-1).astype(jnp.float32)
    cw = t.weight[pl] + cl
    cd = t.depth[pl] + 1
    slot_rank = jnp.cumsum(pv.astype(jnp.int32)) - 1
    slots = jnp.where(pv, t.n_nodes + slot_rank, n_cap)  # n_cap = drop bucket
    fits = slots < n_cap
    keep = pv & fits
    slots_c = jnp.minimum(slots, n_cap - 1)
    t = t._replace(
        tokens=scatter_i32(t.tokens, slots_c, ct, keep),
        parent=scatter_i32(t.parent, slots_c, pl, keep),
        logp=scatter_f32(t.logp, slots_c, cl, keep),
        weight=scatter_f32(t.weight, slots_c, cw, keep),
        depth=scatter_i32(t.depth, slots_c, cd, keep),
        valid=scatter_bool(t.valid, slots_c, jnp.ones_like(keep), keep),
        expanded=scatter_bool(t.expanded, slots_c, jnp.zeros_like(keep), keep),
        kv_row=scatter_i32(t.kv_row, slots_c, jnp.full_like(ct, -1), keep),
        n_nodes=jnp.minimum(t.n_nodes + jnp.sum(keep), n_cap),
    )
    return t


def scatter_i32(arr, idx, val, mask):
    return arr.at[jnp.where(mask, idx, arr.shape[0])].set(val, mode="drop")


def scatter_f32(arr, idx, val, mask):
    return arr.at[jnp.where(mask, idx, arr.shape[0])].set(val.astype(arr.dtype), mode="drop")


def scatter_bool(arr, idx, val, mask):
    return arr.at[jnp.where(mask, idx, arr.shape[0])].set(val, mode="drop")


# -----------------------------------------------------------------------------
# verification batch (paper Alg. 1 line 11-12)
# -----------------------------------------------------------------------------


def select_batch(tree: Tree, bs: int, S_max: int, window: int = 0) -> BatchPlan:
    """Most probable ancestor-closed subgraph of size bs, topologically
    ordered (stable weight sort ⇒ parents precede children); slot 0 = root."""
    n = tree.tokens.shape[0]
    score = jnp.where(tree.valid, tree.weight, NEG)
    order = jnp.argsort(-score, stable=True)  # root (weight 0) first
    node_ids = order[:bs].astype(jnp.int32)
    valid = tree.valid[node_ids] & (score[node_ids] > NEG / 2)
    tokens = jnp.where(valid, tree.tokens[node_ids], 0)
    rows = jnp.where(valid, tree.plen - 1 + jnp.arange(bs, dtype=jnp.int32), -1)
    positions = jnp.where(valid, tree.plen - 1 + tree.depth[node_ids], 0).astype(jnp.int32)
    # parent slot: position of parent node id within node_ids
    par = tree.parent[node_ids]  # [bs]
    eq = node_ids[None, :] == par[:, None]  # [bs, bs]
    has = jnp.any(eq, axis=1) & (par >= 0)
    parent_pos = jnp.where(has, jnp.argmax(eq, axis=1), -1).astype(jnp.int32)
    # target mask: prefix rows [0, plen-1) + in-batch ancestors (incl. self)
    anc = ancestor_matrix(tree)[jnp.maximum(node_ids, 0)][:, jnp.maximum(node_ids, 0)]
    anc &= valid[:, None] & valid[None, :]
    anc = anc | (jnp.eye(bs, dtype=bool) & valid[:, None])
    cols = jnp.arange(S_max, dtype=jnp.int32)
    m_prefix = cols[None, :] < (tree.plen - 1)
    if window:
        m_prefix &= cols[None, :] > (positions[:, None] - window)
    onehot = rows[None, :, None] == cols[None, None, :]
    m_batch = jnp.einsum("ij,xjs->is", anc.astype(jnp.int32), onehot.astype(jnp.int32)) > 0
    mask = (m_prefix | m_batch) & valid[:, None]
    return BatchPlan(node_ids, tokens, rows, positions, mask, parent_pos, valid)


# -----------------------------------------------------------------------------
# greedy verification walk (target side; paper Alg. 1 lines 15-21)
# -----------------------------------------------------------------------------


def verify_walk(plan_tokens, plan_parent_pos, plan_valid, argmax_tokens):
    """Walk the submitted subgraph under the target's greedy choices.

    Returns (acc_pos i32[bs] batch slots of accepted nodes (-1 pad),
             n_acc i32, bonus_token i32, emitted i32[bs+1], n_emitted i32).
    ``emitted`` = accepted tokens then bonus; equals exactly what target-only
    greedy decoding would produce (the correctness invariant).
    """
    bs = plan_tokens.shape[0]

    def step(state, _):
        cur, alive, acc, n_acc = state
        nxt = argmax_tokens[cur]
        is_child = (plan_parent_pos == cur) & plan_valid & (plan_tokens == nxt)
        found = jnp.any(is_child) & alive
        child = jnp.argmax(is_child).astype(jnp.int32)
        acc = jnp.where(found, acc.at[n_acc].set(child), acc)
        n_acc = n_acc + jnp.where(found, 1, 0)
        cur = jnp.where(found, child, cur)
        alive = alive & found
        return (cur, alive, acc, n_acc), None

    acc0 = jnp.full((bs,), -1, jnp.int32)
    (cur, alive, acc, n_acc), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.int32), jnp.ones((), bool), acc0, jnp.zeros((), jnp.int32)), None, length=bs
    )
    bonus = argmax_tokens[cur]
    emitted = jnp.full((bs + 1,), -1, jnp.int32)
    emitted = jnp.where(jnp.arange(bs + 1) < n_acc, jnp.concatenate([plan_tokens[jnp.maximum(acc, 0)], jnp.zeros((1,), jnp.int32)]), -1)
    emitted = emitted.at[n_acc].set(bonus)
    n_emitted = n_acc + 1
    return acc, n_acc, bonus, emitted, n_emitted


def predict_accept(tree: Tree, plan_node_ids, plan_parent_pos, plan_valid):
    """Draft-side prediction of ``verify_walk``'s outcome, from the tree alone.

    The async lookahead (engine ``draft_next_tree``) needs a guess at this
    round's accept path *before* the target's argmax tokens exist on the host.
    The draft's best guess is its own most probable chain: at every step take
    the FIRST plan slot whose parent is the current node — ``select_batch``
    orders slots by a stable weight sort, so the first matching slot is the
    top-weight (most probable) child.  Unlike ``verify_walk`` there is no
    token check: the walk ends only when the current node has no child in
    the plan.

    The predicted bonus is the target's argmax at the last accepted node,
    guessed as the draft's top-probability child of that node in the FULL
    tree (``insert_children`` appends children in descending-prob order, so
    the lowest-indexed child is the top one).  If the node has no child at
    all, -1 — a value the real bonus (a vocab id) can never take, forcing
    the reconcile fallback.

    Returns (acc i32[bs] predicted batch slots (-1 pad), n_acc i32,
    bonus i32).  Prediction is correct iff the target greedily accepts the
    draft's entire top chain AND its bonus equals the draft's top child —
    exactly the event the lookahead tree bets on.
    """
    bs = plan_node_ids.shape[0]

    def step(state, _):
        cur, alive, acc, n_acc = state
        is_child = (plan_parent_pos == cur) & plan_valid
        found = jnp.any(is_child) & alive
        child = jnp.argmax(is_child).astype(jnp.int32)
        acc = jnp.where(found, acc.at[n_acc].set(child), acc)
        n_acc = n_acc + jnp.where(found, 1, 0)
        cur = jnp.where(found, child, cur)
        alive = alive & found
        return (cur, alive, acc, n_acc), None

    acc0 = jnp.full((bs,), -1, jnp.int32)
    (cur, _, acc, n_acc), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.int32), jnp.ones((), bool), acc0, jnp.zeros((), jnp.int32)), None, length=bs
    )
    last_node = plan_node_ids[jnp.maximum(cur, 0)]  # plan slot -> tree node (root if none)
    is_c = (tree.parent == last_node) & tree.valid
    bonus = jnp.where(jnp.any(is_c), tree.tokens[jnp.argmax(is_c)], -1)
    return acc, n_acc, bonus.astype(jnp.int32)


# -----------------------------------------------------------------------------
# re-root + compaction (paper §3.2, Fig. 5)
# -----------------------------------------------------------------------------


def reroot(tree: Tree, batch_node_ids, acc_pos, n_acc, bonus):
    """Re-root at the bonus token; keep the surviving subtree; emit KV plans.

    Returns (tree', MovePlan, FillPlan).
      MovePlan — draft-cache row moves: accepted-path KV into prefix rows,
        surviving expanded nodes compacted into the new tree region.
      FillPlan — accepted tokens whose KV was never computed (unexpanded
        accepted nodes): one masked draft forward fills them (§3.2 "grows
        immediately" generalized).
    """
    n = tree.tokens.shape[0]
    bs = batch_node_ids.shape[0]
    plen_new = tree.plen + n_acc + 1

    # accepted tree nodes, in path order
    acc_nodes = jnp.where(acc_pos >= 0, batch_node_ids[jnp.maximum(acc_pos, 0)], -1)  # [bs]
    acc_ok = jnp.arange(bs) < n_acc
    last_node = jnp.where(n_acc > 0, acc_nodes[jnp.maximum(n_acc - 1, 0)], 0)  # node id of last accepted (root if none)

    # new root: child of last_node carrying the bonus token, if present
    is_new_root = (tree.parent == last_node) & tree.valid & (tree.tokens == bonus)
    root_exists = jnp.any(is_new_root)
    new_root = jnp.where(root_exists, jnp.argmax(is_new_root), -1).astype(jnp.int32)

    # survivors: descendants-or-self of new_root
    anc = ancestor_matrix(tree)
    surv = jnp.where(root_exists, anc[:, jnp.maximum(new_root, 0)] & tree.valid, jnp.zeros((n,), bool))
    surv_nonroot = surv & (jnp.arange(n) != new_root)

    # --- new node index mapping: root -> 0, others ranked by old index -----
    rank = jnp.cumsum(surv_nonroot.astype(jnp.int32)) - 1  # [n]
    new_idx = jnp.where(surv_nonroot, 1 + rank, jnp.where(jnp.arange(n) == new_root, 0, -1))
    m = jnp.sum(surv_nonroot)  # surviving non-root count

    # --- KV row moves ------------------------------------------------------
    # (1) accepted path nodes with KV -> prefix rows plen + i
    src_a = jnp.where(acc_ok, tree.kv_row[jnp.maximum(acc_nodes, 0)], -1)
    dst_a = jnp.where(acc_ok, tree.plen + jnp.arange(bs, dtype=jnp.int32), -1)
    mask_a = acc_ok & (src_a >= 0)
    # (2) new root with KV -> prefix row plen_new - 1
    root_kv = jnp.where(root_exists, tree.kv_row[jnp.maximum(new_root, 0)], -1)
    src_r = jnp.full((1,), -1, jnp.int32).at[0].set(root_kv)
    dst_r = jnp.full((1,), -1, jnp.int32).at[0].set(plen_new - 1)
    mask_r = jnp.array([root_exists]) & (src_r >= 0)
    # (3) surviving expanded non-root nodes -> compacted tree rows
    has_kv = surv_nonroot & (tree.kv_row >= 0)
    kv_rank = jnp.cumsum(has_kv.astype(jnp.int32)) - 1
    src_s = jnp.where(has_kv, tree.kv_row, -1)
    dst_s = jnp.where(has_kv, plen_new + kv_rank, -1)
    move = MovePlan(
        src=jnp.concatenate([src_a, src_r, src_s]),
        dst=jnp.concatenate([dst_a, dst_r, dst_s]),
        mask=jnp.concatenate([mask_a, mask_r, has_kv]),
    )
    next_row_new = plen_new + jnp.sum(has_kv)

    # --- fill plan: accepted nodes WITHOUT KV (their new prefix rows) -------
    fill_tok = jnp.where(acc_ok, tree.tokens[jnp.maximum(acc_nodes, 0)], 0)
    fill_rows = jnp.where(acc_ok & (src_a < 0), dst_a, -1)
    fill = FillPlan(
        tokens=fill_tok,
        rows=fill_rows,
        positions=jnp.where(fill_rows >= 0, fill_rows, 0),  # prefix: position == row
        mask=fill_rows >= 0,
    )

    # --- rebuild node arrays -------------------------------------------------
    gather_src = jnp.argsort(jnp.where(new_idx >= 0, new_idx, n), stable=True)  # new -> old
    live_new = jnp.arange(n) < (1 + m)

    def g(a, fill_val):
        out = a[gather_src]
        return jnp.where(live_new, out, jnp.full_like(out, fill_val))

    root_w = jnp.where(root_exists, tree.weight[jnp.maximum(new_root, 0)], 0.0)
    root_d = jnp.where(root_exists, tree.depth[jnp.maximum(new_root, 0)], 0)
    new_parent = jnp.where(
        live_new,
        jnp.where(
            jnp.arange(n) == 0,
            -1,
            new_idx[jnp.maximum(g(tree.parent, -1), 0)],
        ),
        -1,
    )
    # kv_row remap: moved rows — accepted/surviving nodes get their dst rows
    kv_new_row = jnp.full((n,), -1, jnp.int32)
    kv_new_row = jnp.where(has_kv, dst_s, kv_new_row)  # old-index space
    kv_root_row = jnp.where(root_exists & (root_kv >= 0), plen_new - 1, -1)
    kv_new_row = jnp.where(jnp.arange(n) == new_root, kv_root_row, kv_new_row)

    t = Tree(
        tokens=jnp.where(jnp.arange(n) == 0, bonus, g(tree.tokens, 0)),
        parent=new_parent,
        logp=jnp.where(jnp.arange(n) == 0, 0.0, g(tree.logp, 0.0)),
        weight=jnp.where(jnp.arange(n) == 0, 0.0, g(tree.weight, NEG) - root_w),
        depth=jnp.where(jnp.arange(n) == 0, 0, g(tree.depth, 0) - root_d),
        valid=live_new,
        expanded=jnp.where(
            jnp.arange(n) == 0,
            jnp.where(root_exists, tree.expanded[jnp.maximum(new_root, 0)], False),
            g(tree.expanded, False),
        ),
        kv_row=jnp.where(
            jnp.arange(n) == 0,
            kv_root_row,
            g(kv_new_row, -1),
        ),
        n_nodes=1 + m,
        plen=plen_new,
        next_row=next_row_new,
    )
    return t, move, fill
