"""Profiling-driven resource allocation (paper §3.1 / §5.5).

Before serving, SwiftSpec profiles (1) the draft/target GPU split x and
(2) the number of tree expansions d per round, so drafting and verification
finish nearly simultaneously.  Both are reproduced here:

  profile_times(...)   — wall-time one draft expansion / one target verify
  choose_depth(...)    — d ∈ {r, r+1}, r = floor(t_target / t_draft), pick the
                         higher measured decoding speed (paper §5.5)
  sweep_allocation(...) — try each (x target, k-x draft) device split and keep
                         the fastest average decoding speed (paper Fig. 9)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.obs.clock import monotonic


@dataclasses.dataclass
class ProfileResult:
    t_draft_s: float
    t_target_s: float

    @property
    def ratio(self) -> float:
        return self.t_target_s / max(self.t_draft_s, 1e-9)


def _time_fn(fn: Callable[[], None], iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = monotonic()
    for _ in range(iters):
        fn()
    return (monotonic() - t0) / iters


def profile_times(draft_step: Callable[[], None], target_step: Callable[[], None],
                  iters: int = 5) -> ProfileResult:
    """Time one draft tree expansion and one target verification round."""
    return ProfileResult(
        t_draft_s=_time_fn(draft_step, iters),
        t_target_s=_time_fn(target_step, iters),
    )


def candidate_depths(prof: ProfileResult) -> tuple[int, int]:
    """The paper's d ∈ {r, r+1}, r = floor(t_target / t_draft), r >= 1."""
    r = max(1, int(prof.ratio))
    return r, r + 1


def choose_depth(run_at_depth: Callable[[int], float], prof: ProfileResult) -> int:
    """Run the engine at both candidate depths; keep the faster (tokens/s)."""
    cands = candidate_depths(prof)
    speeds = {d: run_at_depth(d) for d in cands}
    return max(speeds, key=speeds.get)


@dataclasses.dataclass
class AllocationResult:
    n_target: int
    n_draft: int
    tokens_per_s: float


def sweep_allocation(n_devices: int, run_split: Callable[[int, int], float],
                     target_sizes: Sequence[int] | None = None) -> AllocationResult:
    """Paper Fig. 9: sweep x target devices vs (k - x) draft devices.

    Only even target TP degrees are considered (paper §5.5: even degrees
    align with head counts and need less padding).  ``run_split(nt, nd)``
    returns the measured decoding speed for that allocation.
    """
    if target_sizes is None:
        target_sizes = [x for x in range(2, n_devices) if x % 2 == 0] or [max(1, n_devices - 1)]
    best = None
    for nt in target_sizes:
        nd = n_devices - nt
        if nd < 1:
            continue
        tps = run_split(nt, nd)
        if best is None or tps > best.tokens_per_s:
            best = AllocationResult(nt, nd, tps)
    assert best is not None, "no feasible allocation"
    return best
