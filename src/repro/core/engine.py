"""SpecEngine — asynchronous, disaggregated speculative decoding (paper §3.1,
Algorithm 1, Figure 3).

The draft model lives on one device group (submesh), the target on another.
JAX's asynchronous dispatch makes the two jitted programs run concurrently on
disjoint device sets: the verify step for round n is enqueued first, then the
d draft-tree expansions for round n+1 are enqueued on the draft group; the
host blocks only on the tiny verified-token transfer (the paper's NCCL
exchange).  ``mode="serial"`` is the SwiftSpec-base baseline (expand, then
verify, no overlap).

Greedy-verification invariant: the emitted stream equals target-only greedy
decoding token-for-token (tests/test_engine.py).
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kv as kvm
from repro.core import tree as T
from repro.obs.clock import monotonic
from repro.obs.trace import NOOP_SPAN, NULL_TRACER
from repro.sharding import use_mesh


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    bs: int = 8  # target verification batch (paper §5.5: 8)
    w: int = 4  # draft leaves expanded per step (paper §5.5: 8)
    c: int = 2  # children proposed per expanded leaf
    d: int = 3  # tree expansions per round (profiled: ~t_target/t_draft)
    n_cap: int = 64  # tree node capacity
    mode: str = "parallel"  # "parallel" | "serial"
    max_new: int = 64
    eos_id: int = -1  # -1: never stop early
    draft_bypass: bool = False  # straggler mitigation: verify root-only chain
    async_rounds: bool = False  # pipeline rounds: draft N+1's tree while N verifies


@dataclasses.dataclass
class SpecStats:
    """Per-row exact accounting: ``emitted_rows``/``accepted_rows`` hold one
    running total per batch row, accumulated round by round; the scalar
    ``emitted``/``accepted`` views are per-row means derived at read time
    (the old per-round ``sum // B`` floor silently dropped tokens whenever
    rows emitted unequal counts)."""

    rounds: int = 0
    draft_steps: int = 0
    wall_s: float = 0.0
    emitted_rows: np.ndarray | None = None  # i64[B] per-row emitted totals
    accepted_rows: np.ndarray | None = None  # i64[B] per-row accepted totals
    spec_rounds: int = 0  # rounds run through the async lookahead path
    spec_commits: int = 0  # of those, rounds whose lookahead tree was adopted

    def add_round(self, n_emitted, n_accepted):
        n_emitted = np.asarray(n_emitted, np.int64)
        if self.emitted_rows is None:
            self.emitted_rows = np.zeros_like(n_emitted)
            self.accepted_rows = np.zeros_like(n_emitted)
        self.emitted_rows += n_emitted
        self.accepted_rows += np.asarray(n_accepted, np.int64)
        self.rounds += 1

    @property
    def emitted(self) -> float:
        return 0.0 if self.emitted_rows is None else float(self.emitted_rows.mean())

    @property
    def accepted(self) -> float:
        return 0.0 if self.accepted_rows is None else float(self.accepted_rows.mean())

    @property
    def total_emitted(self) -> int:
        return 0 if self.emitted_rows is None else int(self.emitted_rows.sum())

    @property
    def tokens_per_round(self) -> float:
        return self.emitted / max(self.rounds, 1)

    @property
    def compression_ratio(self) -> float:
        """Paper's metric: tokens per target-model inference."""
        return self.tokens_per_round


@dataclasses.dataclass
class EngineState:
    """Device-side state of one decode batch, advanced by ``SpecEngine.step``.

    Treat it linearly: the jitted steps donate their cache/tree buffers, so a
    state consumed by step()/admit_slot()/release_slot() must not be reused —
    always thread the returned state forward (generate() and the serving
    runtime both do)."""

    tcache: Any  # target KV cache [U, B, S_max_t, ...]
    dcache: Any  # draft KV cache [U, B, S_max_d, ...]
    tr: Any  # stacked Tree, leaves [B, ...]
    plan: Any  # BatchPlan for the NEXT verification, leaves [B, ...]


@dataclasses.dataclass(frozen=True)
class StepResult:
    """Host-side outcome of one round, per batch row."""

    emitted: np.ndarray  # i32[B, bs+1] verified tokens (accepted + bonus)
    n_emitted: np.ndarray  # i32[B]
    n_accepted: np.ndarray  # i32[B]


@dataclasses.dataclass
class RoundInFlight:
    """One dispatched-but-unreconciled speculative round.

    Created by ``EngineSession.dispatch_verify`` + ``draft_next_tree``,
    consumed exactly once by ``EngineSession.reconcile``.  Everything here is
    a device future except ``draft_steps``; nothing has crossed to the host
    yet.  The owning session's ``state`` is consumed (its buffers donated)
    while a round is in flight — the fresh state is reassembled from these
    fields at reconcile time.
    """

    plan: Any  # BatchPlan actually submitted to verify (post-bypass)
    tcache: Any  # verify-updated target cache (correct regardless of outcome)
    verify: tuple  # (acc_pos, n_acc, bonus, emitted, n_emitted) device futures
    snapshot: tuple | None = None  # (tr, dcache) post-expansion, pre-reroot
    lookahead: tuple | None = None  # (tr, dcache, plan) drafted for round N+1
    pred: tuple | None = None  # (acc_pos, n_acc, bonus) predicted outcome
    draft_steps: int = 0
    verify_span: Any = NOOP_SPAN  # open until the reconcile sync (verify window)


def _effective_depth(depth: int | None, default: int) -> int:
    """Resolve a round's draft depth: a concrete Python int (a host-side
    loop trip count — never traced) with ``None`` meaning the config's
    global ``d``."""
    if depth is None:
        return default
    d = int(depth)
    if d < 1:
        raise ValueError(f"draft depth must be >= 1, got {depth}")
    return d


def absorb_emitted(out: list, emitted_row, n_emitted: int, max_new: int, eos_id: int):
    """Append one row's verified tokens to ``out`` until EOS or ``max_new``.

    The single definition of truncation semantics (token appended first, then
    tested) shared by generate() and the serving runtime — the byte-identical
    serving contract depends on both paths stopping on exactly the same token.
    Returns (new_tokens, done)."""
    new = []
    for t in emitted_row[:n_emitted].tolist():
        out.append(int(t))
        new.append(int(t))
        if (eos_id >= 0 and t == eos_id) or len(out) >= max_new:
            return new, True
    return new, False


class SpecEngine:
    """Tree-based speculative decoding for attention architectures."""

    def __init__(self, target, draft, cfg: SpecConfig, S_max_t: int, S_max_d: int,
                 mesh_target=None, mesh_draft=None):
        self.target, self.draft, self.cfg = target, draft, cfg
        self.S_max_t, self.S_max_d = S_max_t, S_max_d
        self.mesh_target, self.mesh_draft = mesh_target, mesh_draft
        window = target.cfg.sliding_window
        c = cfg
        if c.async_rounds and c.mode != "parallel":
            raise ValueError(
                f"async_rounds requires mode='parallel' (got mode={c.mode!r}): "
                "the lookahead pipeline IS the parallel overlap")

        # ----- jitted draft-side steps ------------------------------------
        def expand(dparams, tr, dcache):
            leaf_ids, leaf_valid = jax.vmap(lambda t: T.select_leaves(t, c.w))(tr)
            tokens, rows, positions, mask, _ = jax.vmap(
                lambda t, li, lv: T.leaf_inputs(t, li, lv, S_max_d, draft.cfg.sliding_window)
            )(tr, leaf_ids, leaf_valid)
            logits, dcache = draft.spec_forward(dparams, dcache, tokens, positions, rows, mask)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32))
            top_lp, top_tok = jax.lax.top_k(lp, c.c)  # [B,w,c]
            tr = jax.vmap(T.insert_children)(tr, leaf_ids, leaf_valid, rows, top_tok, top_lp)
            return tr, dcache

        def select_plan(tr):
            return jax.vmap(lambda t: T.select_batch(t, c.bs, S_max_t, window))(tr)

        # the re-root is three separately-dispatched programs so the host can
        # put a `kv_move` tracer span around exactly the cache-reorganization
        # dispatch (the cost the fused kernels attack):
        #   reroot   — tree bookkeeping; emits the MovePlan + FillPlan
        #   kv_move  — apply the MovePlan to the draft cache (donating on the
        #              committed path, snapshot-preserving on the lookahead)
        #   fill     — forward pass for accepted-but-unexpanded prefix KV
        def reroot(tr, node_ids, acc_pos, n_acc, bonus):
            return jax.vmap(T.reroot)(tr, node_ids, acc_pos, n_acc, bonus)

        def kv_move(dcache, src, dst, mask, *, donate):
            dcache = kvm.apply_moves(dcache, src, dst, mask, donate=donate)
            return kvm.set_length(dcache, 0)  # length bookkeeping via tree.plen

        def fill_prefix(dparams, dcache, fill):
            # fill missing prefix KV (accepted-but-unexpanded tokens)
            cols = jnp.arange(S_max_d, dtype=jnp.int32)
            fmask = (cols[None, None, :] <= fill.rows[:, :, None]) & fill.mask[:, :, None]
            _, dcache = draft.spec_forward(
                dparams, dcache, fill.tokens, fill.positions, fill.rows, fmask
            )
            return dcache

        def seed(tr, root_tok, plen, root_logits):
            return jax.vmap(lambda t, tok, lg: T.seed_root(t, tok, plen, lg, c.c))(
                tr, root_tok, root_logits
            )

        # ----- jitted target-side steps -------------------------------------
        def verify(tparams, tcache, tokens, positions, rows, mask, parent_pos, valid):
            logits, tcache = self.target.spec_forward(tparams, tcache, tokens, positions, rows, mask)
            argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            acc_pos, n_acc, bonus, emitted, n_emitted = jax.vmap(T.verify_walk)(
                tokens, parent_pos, valid, argmax
            )
            # compaction plan: accepted rows -> prefix  (target Fig.5
            # analogue); applied by the separately-dispatched _compact so the
            # reorganization cost is visible under its own kv_move span
            bs = tokens.shape[1]
            plen = rows[:, 0] + 1  # root row = plen-1
            src = jnp.where(acc_pos >= 0, jnp.take_along_axis(rows, jnp.maximum(acc_pos, 0), axis=1), -1)
            dst = plen[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]
            mmask = (jnp.arange(bs)[None, :] < n_acc[:, None]) & (src >= 0)
            return acc_pos, n_acc, bonus, emitted, n_emitted, tcache, (src, dst, mmask)

        def compact(tcache, src, dst, mask):
            return kvm.apply_moves(tcache, src, dst, mask, donate=True)

        self._expand = jax.jit(expand, donate_argnums=(1, 2))
        self._select_plan = jax.jit(select_plan)
        self._reroot = jax.jit(reroot, donate_argnums=(0,))
        self._kv_move = jax.jit(functools.partial(kv_move, donate=True), donate_argnums=(0,))
        # async lookahead twins: the speculative re-root must NOT donate —
        # the pre-reroot (tr, dcache) snapshot stays alive as the reconcile
        # fallback basis until the verify outcome lands on the host (and the
        # non-donating kv_move routes to the snapshot-preserving kernel)
        self._spec_reroot = jax.jit(reroot)
        self._spec_kv_move = jax.jit(functools.partial(kv_move, donate=False))
        self._fill = jax.jit(fill_prefix, donate_argnums=(1,))
        self._predict = jax.jit(jax.vmap(T.predict_accept))
        self._seed = jax.jit(seed, static_argnums=(2,))
        self._verify = jax.jit(verify, donate_argnums=(1,))
        self._compact = jax.jit(compact, donate_argnums=(0,))
        self._dprefill = jax.jit(lambda p, t, S: draft.prefill(p, tokens=t, S_max=S), static_argnums=(2,))
        self._tprefill = jax.jit(lambda p, t, S: target.prefill(p, tokens=t, S_max=S), static_argnums=(2,))
        # per-slot lifecycle (continuous batching); slot/plen are traced so
        # one compile covers every slot index and prompt length
        self._install = jax.jit(kvm.install_slot, donate_argnums=(0,))
        self._zero_slot = jax.jit(kvm.zero_slot, donate_argnums=(0,))
        self._reset_slot = jax.jit(T.reset_slot, donate_argnums=(0,))
        self._seed_slot = jax.jit(
            lambda tr, slot, tok, plen, lg: T.seed_slot(tr, slot, tok, plen, lg, c.c),
            donate_argnums=(0,),
        )

    # ------------------------------------------------------------------
    # state lifecycle (used by generate() below and by serving/runtime.py)
    # ------------------------------------------------------------------
    @property
    def grow_per_round(self) -> int:
        """Expansions needed to refill a re-rooted tree to >= bs nodes."""
        c = self.cfg
        return max(1, -(-(c.bs) // (c.w * c.c)))

    @property
    def plen_budget(self) -> int:
        """Largest per-row prefix length the caches can safely carry into one
        more round: verify rows reach plen-1+bs and the re-rooted tree needs
        another bs of headroom, so stop ``2*bs`` short of the tighter cache.

        The single definition of the KV-budget bound, shared by ``generate()``
        and the serving runtimes — if the two ever drift, a request near the
        budget stops at different tokens solo vs served, silently breaking the
        byte-identical contract."""
        return min(self.S_max_t, self.S_max_d) - 2 * self.cfg.bs

    def init_state(self, B: int) -> EngineState:
        """Empty B-slot serving state: zero caches, parked (invalid) trees.

        Parked slots are inert: their plans carry no valid node, so verify
        writes nothing and expand skips them; the runtime discards whatever
        they "emit"."""
        tcache = self.target.init_cache(B, self.S_max_t)
        dcache = self.draft.init_cache(B, self.S_max_d)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), T.init_tree(self.cfg.n_cap))
        with use_mesh(self.mesh_draft):
            plan = self._select_plan(tr)
        return EngineState(tcache, dcache, tr, plan)

    def _prefill_state(self, tparams, dparams, prompt) -> EngineState:
        """Whole-batch prefill + tree seed + initial growth (all rows start
        together — the generate() path)."""
        c = self.cfg
        B, P = prompt.shape
        with use_mesh(self.mesh_draft):
            dlogits, dcache = self._dprefill(dparams, jnp.asarray(prompt), self.S_max_d)
        with use_mesh(self.mesh_target):
            _, tcache = self._tprefill(tparams, jnp.asarray(prompt), self.S_max_t)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), T.init_tree(c.n_cap))
        root_tok = jnp.asarray(prompt[:, -1], jnp.int32)
        with use_mesh(self.mesh_draft):
            tr = self._seed(tr, root_tok, P, dlogits[:, -1, :])
            for _ in range(self.grow_per_round):
                tr, dcache = self._expand(dparams, tr, dcache)
            plan = self._select_plan(tr)
        return EngineState(tcache, dcache, tr, plan)

    def session(self, tparams, dparams, *, state: EngineState | None = None,
                n_slots: int | None = None, tracer=None, track: str = "engine") -> "EngineSession":
        """Bind params (+ optional state and tracer) into an ``EngineSession``
        — the round API: ``session.step()`` / ``admit_slot`` / ``release_slot``
        / ``generate``, plus the async phase methods ``dispatch_verify`` /
        ``draft_next_tree`` / ``reconcile``.  Pass ``n_slots`` to start from an
        empty parked serving state."""
        if state is None and n_slots is not None:
            state = self.init_state(n_slots)
        return EngineSession(
            engine=self, tparams=tparams, dparams=dparams, state=state,
            tracer=tracer if tracer is not None else NULL_TRACER, track=track)

    # --- one-release deprecation shims over the session API ---------------
    def admit_slot(self, tparams, dparams, state: EngineState, slot: int, prompt) -> EngineState:
        """Deprecated: use ``session(tparams, dparams, state=...).admit_slot``."""
        warnings.warn(
            "SpecEngine.admit_slot(tparams, dparams, state, ...) is deprecated; "
            "bind an EngineSession via SpecEngine.session(...) instead",
            DeprecationWarning, stacklevel=2)
        s = self.session(tparams, dparams, state=state)
        s.admit_slot(slot, prompt)
        return s.state

    def release_slot(self, state: EngineState, slot: int) -> EngineState:
        """Deprecated: use ``EngineSession.release_slot``.

        The old positional form never carried params, so the shim binds None —
        release touches no model weights."""
        warnings.warn(
            "SpecEngine.release_slot(state, slot) is deprecated; "
            "bind an EngineSession via SpecEngine.session(...) instead",
            DeprecationWarning, stacklevel=2)
        s = self.session(None, None, state=state)
        s.release_slot(slot)
        return s.state

    def step(self, tparams, dparams, state: EngineState, stats: SpecStats | None = None,
             tracer=None, trace_track: str = "engine"):
        """Deprecated: use ``EngineSession.step``.  Returns (state', StepResult)."""
        warnings.warn(
            "SpecEngine.step(tparams, dparams, state, ...) is deprecated; "
            "bind an EngineSession via SpecEngine.session(...) instead",
            DeprecationWarning, stacklevel=2)
        s = self.session(tparams, dparams, state=state, tracer=tracer, track=trace_track)
        res = s.step(stats=stats)
        return s.state, res

    def generate(self, tparams, dparams, prompt, max_new=None):
        """Deprecated: use ``session(tparams, dparams).generate(prompt)``."""
        warnings.warn(
            "SpecEngine.generate(tparams, dparams, prompt) is deprecated; "
            "use SpecEngine.session(tparams, dparams).generate(prompt)",
            DeprecationWarning, stacklevel=2)
        return self.session(tparams, dparams).generate(prompt, max_new=max_new)

    def profile(self, tparams, dparams, prompt, iters: int = 3):
        """Paper §5.5 profile pass: wall-time one draft expansion and one
        target verification (jits warmed first).  Returns ProfileResult."""
        from repro.core.scheduler import ProfileResult

        c = self.cfg
        B, P = prompt.shape
        with use_mesh(self.mesh_draft):
            dlogits, dcache = self._dprefill(dparams, jnp.asarray(prompt), self.S_max_d)
        with use_mesh(self.mesh_target):
            _, tcache = self._tprefill(tparams, jnp.asarray(prompt), self.S_max_t)
        t0tree = T.init_tree(c.n_cap)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), t0tree)
        with use_mesh(self.mesh_draft):
            tr = self._seed(tr, jnp.asarray(prompt[:, -1], jnp.int32), P, dlogits[:, -1, :])
            tr, dcache = self._expand(dparams, tr, dcache)  # warm
            plan = self._select_plan(tr)

        def draft_once():
            nonlocal tr, dcache
            with use_mesh(self.mesh_draft):
                tr, dcache = self._expand(dparams, tr, dcache)
                jax.block_until_ready(tr.tokens)

        def target_once():
            nonlocal tcache
            with use_mesh(self.mesh_target):
                out = self._verify(tparams, tcache, plan.tokens, plan.positions,
                                   plan.rows, plan.mask, plan.parent_pos, plan.valid)
                tcache = self._compact(out[5], *out[6])
                jax.block_until_ready(out[0])

        target_once()  # warm
        t0 = monotonic()
        for _ in range(iters):
            draft_once()
        t_d = (monotonic() - t0) / iters
        t0 = monotonic()
        for _ in range(iters):
            target_once()
        t_t = (monotonic() - t0) / iters
        return ProfileResult(t_draft_s=t_d, t_target_s=t_t)

    def _bypass(self, plan):
        """Straggler mitigation: degenerate to root-only verification."""
        keep = jnp.arange(plan.tokens.shape[1]) == 0
        return T.BatchPlan(
            node_ids=plan.node_ids,
            tokens=plan.tokens,
            rows=jnp.where(keep[None, :], plan.rows, -1),
            positions=plan.positions,
            mask=plan.mask & keep[None, :, None],
            parent_pos=plan.parent_pos,
            valid=plan.valid & keep[None, :],
        )


@dataclasses.dataclass
class EngineSession:
    """Params + state + tracer bound into one decode session — the round API.

    Replaces the positional ``(tparams, dparams, state)`` threading: the
    session owns the linear ``EngineState`` and advances it in place.  One
    session per serving replica (``EngineStepper``) or per solo ``generate``.

    Lockstep round (``async_rounds=False``)::

        res = session.step()          # verify → expand → sync → reroot/grow

    Pipelined round (``async_rounds=True``) — the paper's headline overlap::

        rif = session.begin_round()   # dispatch_verify + draft_next_tree
        ...                           # other replicas dispatch here
        res = session.reconcile(rif)  # sync, adopt lookahead or roll back

    Between ``begin_round`` and ``reconcile`` the session state is consumed
    (buffers donated into the round) — ``admit_slot``/``release_slot``/
    ``step`` must not run until the in-flight round reconciles.
    """

    engine: SpecEngine
    tparams: Any
    dparams: Any
    state: EngineState | None = None
    tracer: Any = NULL_TRACER
    track: str = "engine"
    _inflight: RoundInFlight | None = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------
    # slot lifecycle
    # ------------------------------------------------------------------
    def admit_slot(self, slot: int, prompt) -> None:
        """Admit one request into batch row ``slot`` of the session state.

        The request is prefilled solo ([1, P] — byte-identical numerics to a
        solo generate() start), its cache rows installed into row ``slot`` of
        both serving caches, its tree re-seeded with its own prefix length,
        and the batch grown/re-planned so the next verify covers it.
        Neighboring rows' caches and trees are untouched (they only gain
        extra draft expansions, which never changes emitted tokens — the
        greedy-verification invariant)."""
        self._check_quiescent("admit_slot")
        eng, state = self.engine, self.state
        prompt = np.asarray(prompt, np.int32).reshape(1, -1)
        P = prompt.shape[1]
        with use_mesh(eng.mesh_draft):
            dlogits, dcache1 = eng._dprefill(self.dparams, jnp.asarray(prompt), eng.S_max_d)
        with use_mesh(eng.mesh_target):
            _, tcache1 = eng._tprefill(self.tparams, jnp.asarray(prompt), eng.S_max_t)
            tcache = eng._install(state.tcache, tcache1, slot)
        with use_mesh(eng.mesh_draft):
            dcache = eng._install(state.dcache, dcache1, slot)
            tr = eng._seed_slot(
                state.tr, slot, jnp.asarray(prompt[0, -1], jnp.int32),
                jnp.asarray(P, jnp.int32), dlogits[0, -1, :],
            )
            for _ in range(eng.grow_per_round):
                tr, dcache = eng._expand(self.dparams, tr, dcache)
            plan = eng._select_plan(tr)
        self.state = EngineState(tcache, dcache, tr, plan)

    def release_slot(self, slot: int) -> None:
        """Retire batch row ``slot``: park its tree and physically zero its
        KV rows in both caches, so no state can leak into the next occupant."""
        self._check_quiescent("release_slot")
        eng, state = self.engine, self.state
        with use_mesh(eng.mesh_target):
            tcache = eng._zero_slot(state.tcache, slot)
        with use_mesh(eng.mesh_draft):
            dcache = eng._zero_slot(state.dcache, slot)
            tr = eng._reset_slot(state.tr, slot)
            plan = eng._select_plan(tr)
        self.state = EngineState(tcache, dcache, tr, plan)

    # ------------------------------------------------------------------
    # the round, lockstep
    # ------------------------------------------------------------------
    def step(self, stats: SpecStats | None = None,
             depth: int | None = None) -> StepResult:
        """One round for every slot.  With ``async_rounds`` this is the
        degenerate pipeline (begin + reconcile back-to-back — same tokens,
        no cross-replica overlap); the serving runtime splits the two calls
        to keep one verify and one draft outstanding per replica.

        ``depth`` is the round's effective draft depth — how many tree
        expansions this round runs — as a plain Python int (None: the
        config's global ``d``).  It is a loop trip count on the host, never
        a traced value, so varying it round to round compiles nothing new:
        the jitted ``_expand`` program is shared by every depth.  Depth only
        changes how much of the greedy continuation each round verifies
        (the adaptive-depth scheduler's lever); the emitted stream itself is
        depth-invariant — greedy verification pins it to target-only greedy
        decoding (tests/test_scheduler.py asserts byte-identity under
        arbitrary per-round depth schedules).

        Rows at different decode depths coexist: all per-row quantities
        (prefix length, masks, acceptance) live in the vmapped tree, so the
        serving runtime can drive rows with mixed progress through the same
        jitted round.

        The session ``tracer`` records the round's host-side phase spans —
        verify_dispatch / draft_expand / sync_emitted / reroot_grow (plus
        draft_lookahead / reconcile on the async path) on ``track`` (one
        track per serving replica); the default NULL_TRACER path is free."""
        if self.engine.cfg.async_rounds:
            return self.reconcile(self.begin_round(depth=depth), stats=stats)
        self._check_quiescent("step")
        eng, obs, track = self.engine, self.tracer, self.track
        c, state = eng.cfg, self.state
        d_eff = _effective_depth(depth, c.d)
        plan = eng._bypass(state.plan) if c.draft_bypass else state.plan
        tr, dcache = state.tr, state.dcache
        draft_steps = 0
        # --- dispatch verification on the target group (async) -------------
        with obs.span("verify_dispatch", track):
            with use_mesh(eng.mesh_target):
                acc_pos, n_acc, bonus, emitted, n_emitted, tcache, mv = eng._verify(
                    self.tparams, state.tcache, plan.tokens, plan.positions, plan.rows,
                    plan.mask, plan.parent_pos, plan.valid,
                )
                with obs.span("kv_move", track):
                    tcache = eng._compact(tcache, *mv)
        # --- concurrently: d tree expansions on the draft group ------------
        if c.mode == "parallel":
            with obs.span("draft_expand", track):
                with use_mesh(eng.mesh_draft):
                    for _ in range(d_eff):
                        tr, dcache = eng._expand(self.dparams, tr, dcache)
                    draft_steps += d_eff
        # --- sync point: verified tokens cross groups (host-mediated) ------
        with obs.span("sync_emitted", track):
            # the round's ONE designated host sync: the verified-token
            # transfer (paper's NCCL exchange), fused — everything else async
            emitted_h, n_emitted_h, n_acc_h = jax.device_get((emitted, n_emitted, n_acc))  # repro: disable=HOTSYNC — designated sync point
        # --- re-root, fill, grow, select next batch (draft group) ----------
        with obs.span("reroot_grow", track):
            with use_mesh(eng.mesh_draft):
                tr, move, fillp = eng._reroot(tr, plan.node_ids, acc_pos, n_acc, bonus)
                with obs.span("kv_move", track):
                    dcache = eng._kv_move(dcache, move.src, move.dst, move.mask)
                dcache = eng._fill(self.dparams, dcache, fillp)
                n_grow = d_eff if c.mode == "serial" else eng.grow_per_round
                for _ in range(n_grow):
                    tr, dcache = eng._expand(self.dparams, tr, dcache)
                draft_steps += n_grow
                new_plan = eng._select_plan(tr)
        self.state = EngineState(tcache, dcache, tr, new_plan)
        if stats is not None:
            stats.add_round(n_emitted_h, n_acc_h)
            stats.draft_steps += draft_steps
        return StepResult(np.asarray(emitted_h), np.asarray(n_emitted_h), np.asarray(n_acc_h))

    # ------------------------------------------------------------------
    # the round, disaggregated (async_rounds)
    # ------------------------------------------------------------------
    def begin_round(self, depth: int | None = None) -> RoundInFlight:
        """Dispatch one full round without syncing: verify on the target
        group, then the speculative next-round draft on the draft group.
        ``depth``: this round's effective draft depth (see ``step``)."""
        rif = self.dispatch_verify()
        return self.draft_next_tree(rif, depth=depth)

    def dispatch_verify(self) -> RoundInFlight:
        """Enqueue this round's target verification; return the in-flight
        round handle.  No host sync — results stay device futures.  The
        ``verify_dispatch`` span is left OPEN until the reconcile sync, so
        on the trace it is the round's verify window and the overlap with
        ``draft_lookahead`` is directly measurable."""
        self._check_quiescent("dispatch_verify")
        eng, state = self.engine, self.state
        plan = eng._bypass(state.plan) if eng.cfg.draft_bypass else state.plan
        span = self.tracer.begin("verify_dispatch", self.track)
        with use_mesh(eng.mesh_target):
            acc_pos, n_acc, bonus, emitted, n_emitted, tcache, mv = eng._verify(
                self.tparams, state.tcache, plan.tokens, plan.positions, plan.rows,
                plan.mask, plan.parent_pos, plan.valid,
            )
            with self.tracer.span("kv_move", self.track):
                tcache = eng._compact(tcache, *mv)
        rif = RoundInFlight(
            plan=plan, tcache=tcache,
            verify=(acc_pos, n_acc, bonus, emitted, n_emitted),
            verify_span=span,
        )
        self._inflight = rif
        return rif

    def draft_next_tree(self, rif: RoundInFlight,
                        depth: int | None = None) -> RoundInFlight:
        """While verify runs: finish this round's expansions (``depth`` of
        them — the round's effective draft depth, a host loop count; None
        means the config's global ``d``), predict the accept path
        (``tree.predict_accept``), and draft round N+1's tree on the
        predicted-accept seed — the paper's draft-ahead.  The pre-reroot
        (tr, dcache) snapshot is retained (the speculative re-root does not
        donate), so ``reconcile`` can roll back a rejected seed exactly."""
        eng, c = self.engine, self.engine.cfg
        d_eff = _effective_depth(depth, c.d)
        tr, dcache = self.state.tr, self.state.dcache
        with self.tracer.span("draft_lookahead", self.track):
            with use_mesh(eng.mesh_draft):
                for _ in range(d_eff):
                    tr, dcache = eng._expand(self.dparams, tr, dcache)
                rif.draft_steps += d_eff
                # post-expansion, pre-reroot: the rollback point
                rif.snapshot = (tr, dcache)
                rif.pred = eng._predict(
                    tr, rif.plan.node_ids, rif.plan.parent_pos, rif.plan.valid)
                pred_acc, pred_n, pred_bonus = rif.pred
                la_tr, move, fillp = eng._spec_reroot(
                    tr, rif.plan.node_ids, pred_acc, pred_n, pred_bonus)
                with self.tracer.span("kv_move", self.track):
                    # snapshot-preserving move: dcache stays alive for rollback
                    la_dcache = eng._spec_kv_move(dcache, move.src, move.dst, move.mask)
                la_dcache = eng._fill(self.dparams, la_dcache, fillp)
                for _ in range(eng.grow_per_round):
                    la_tr, la_dcache = eng._expand(self.dparams, la_tr, la_dcache)
                rif.draft_steps += eng.grow_per_round
                rif.lookahead = (la_tr, la_dcache, eng._select_plan(la_tr))
        return rif

    def reconcile(self, rif: RoundInFlight, stats: SpecStats | None = None,
                  live=None) -> StepResult:
        """Sync the verify outcome and resolve the speculation: adopt the
        lookahead tree when the predicted accept path held, else roll back
        to the retained snapshot and re-root on the actual path (the exact
        lockstep tail, one round late).

        ``live``: optional bool[B] row occupancy mask — prediction mismatches
        on parked rows are ignored (their trees never reach verification and
        admission fully overwrites the row).  Emitted tokens always come from
        the actual verify, so outputs are byte-identical to lockstep on both
        branches."""
        eng, obs, track = self.engine, self.tracer, self.track
        acc_pos, n_acc, bonus, emitted, n_emitted = rif.verify
        pred_acc, pred_n, pred_bonus = rif.pred
        with obs.span("sync_emitted", track):
            # the round's ONE designated host sync: verified tokens and the
            # prediction verdict cross in a single fused transfer
            (emitted_h, n_emitted_h, n_acc_h, acc_h, bonus_h, pred_acc_h, pred_n_h, pred_bonus_h) = jax.device_get(  # repro: disable=HOTSYNC — designated sync point
                (emitted, n_emitted, n_acc, acc_pos, bonus, pred_acc, pred_n, pred_bonus))
        rif.verify_span.end()
        ok = ((pred_n_h == n_acc_h) & (pred_bonus_h == bonus_h)
              & (pred_acc_h == acc_h).all(axis=1))
        if live is not None:
            ok = ok | ~np.asarray(live, bool)
        draft_steps = rif.draft_steps
        if ok.all():
            # seed held for every live row: round N+1's tree is already drafted
            tr, dcache, new_plan = rif.lookahead
            if stats is not None:
                stats.spec_commits += 1
        else:
            with obs.span("reconcile", track):
                with use_mesh(eng.mesh_draft):
                    tr, dcache = rif.snapshot
                    tr, move, fillp = eng._reroot(
                        tr, rif.plan.node_ids, acc_pos, n_acc, bonus)
                    with obs.span("kv_move", track):
                        # actual-path move consumes the snapshot (donating)
                        dcache = eng._kv_move(dcache, move.src, move.dst, move.mask)
                    dcache = eng._fill(self.dparams, dcache, fillp)
                    for _ in range(eng.grow_per_round):
                        tr, dcache = eng._expand(self.dparams, tr, dcache)
                    draft_steps += eng.grow_per_round
                    new_plan = eng._select_plan(tr)
        self.state = EngineState(rif.tcache, dcache, tr, new_plan)
        self._inflight = None
        if stats is not None:
            stats.spec_rounds += 1
            stats.add_round(n_emitted_h, n_acc_h)
            stats.draft_steps += draft_steps
        return StepResult(np.asarray(emitted_h), np.asarray(n_emitted_h), np.asarray(n_acc_h))

    # ------------------------------------------------------------------
    def generate(self, prompt, max_new=None):
        """prompt: np.ndarray [B, P] int32. Returns (tokens [B, <=max_new] list, stats).

        Rebuilds the session state from a whole-batch prefill of ``prompt``
        (any prior state is discarded), then loops rounds."""
        eng, c = self.engine, self.engine.cfg
        max_new = max_new or c.max_new
        B, P = prompt.shape
        t0 = monotonic()

        self.state = eng._prefill_state(self.tparams, self.dparams, prompt)
        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        stats = SpecStats()
        rounds_cap = max_new + 2  # greedy emits >=1 token/round

        for _ in range(rounds_cap):
            longest = 0 if stats.emitted_rows is None else int(stats.emitted_rows.max())
            if done.all() or (P + longest) >= eng.plen_budget:
                break
            res = self.step(stats=stats)
            for b in range(B):
                if not done[b]:
                    _, done[b] = absorb_emitted(
                        out[b], res.emitted[b], res.n_emitted[b], max_new, c.eos_id)

        stats.wall_s = monotonic() - t0
        return out, stats

    @property
    def plen_budget(self) -> int:
        return self.engine.plen_budget

    def _check_quiescent(self, what: str) -> None:
        if self._inflight is not None:
            raise RuntimeError(
                f"EngineSession.{what} called with a round in flight; "
                "reconcile() the outstanding RoundInFlight first — the state's "
                "buffers are donated into the round")
