"""SpecEngine — asynchronous, disaggregated speculative decoding (paper §3.1,
Algorithm 1, Figure 3).

The draft model lives on one device group (submesh), the target on another.
JAX's asynchronous dispatch makes the two jitted programs run concurrently on
disjoint device sets: the verify step for round n is enqueued first, then the
d draft-tree expansions for round n+1 are enqueued on the draft group; the
host blocks only on the tiny verified-token transfer (the paper's NCCL
exchange).  ``mode="serial"`` is the SwiftSpec-base baseline (expand, then
verify, no overlap).

Greedy-verification invariant: the emitted stream equals target-only greedy
decoding token-for-token (tests/test_engine.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kv as kvm
from repro.core import tree as T
from repro.obs.clock import monotonic
from repro.obs.trace import NULL_TRACER
from repro.sharding import use_mesh


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    bs: int = 8  # target verification batch (paper §5.5: 8)
    w: int = 4  # draft leaves expanded per step (paper §5.5: 8)
    c: int = 2  # children proposed per expanded leaf
    d: int = 3  # tree expansions per round (profiled: ~t_target/t_draft)
    n_cap: int = 64  # tree node capacity
    mode: str = "parallel"  # "parallel" | "serial"
    max_new: int = 64
    eos_id: int = -1  # -1: never stop early
    draft_bypass: bool = False  # straggler mitigation: verify root-only chain


@dataclasses.dataclass
class SpecStats:
    """Per-row exact accounting: ``emitted_rows``/``accepted_rows`` hold one
    running total per batch row, accumulated round by round; the scalar
    ``emitted``/``accepted`` views are per-row means derived at read time
    (the old per-round ``sum // B`` floor silently dropped tokens whenever
    rows emitted unequal counts)."""

    rounds: int = 0
    draft_steps: int = 0
    wall_s: float = 0.0
    emitted_rows: np.ndarray | None = None  # i64[B] per-row emitted totals
    accepted_rows: np.ndarray | None = None  # i64[B] per-row accepted totals

    def add_round(self, n_emitted, n_accepted):
        n_emitted = np.asarray(n_emitted, np.int64)
        if self.emitted_rows is None:
            self.emitted_rows = np.zeros_like(n_emitted)
            self.accepted_rows = np.zeros_like(n_emitted)
        self.emitted_rows += n_emitted
        self.accepted_rows += np.asarray(n_accepted, np.int64)
        self.rounds += 1

    @property
    def emitted(self) -> float:
        return 0.0 if self.emitted_rows is None else float(self.emitted_rows.mean())

    @property
    def accepted(self) -> float:
        return 0.0 if self.accepted_rows is None else float(self.accepted_rows.mean())

    @property
    def total_emitted(self) -> int:
        return 0 if self.emitted_rows is None else int(self.emitted_rows.sum())

    @property
    def tokens_per_round(self) -> float:
        return self.emitted / max(self.rounds, 1)

    @property
    def compression_ratio(self) -> float:
        """Paper's metric: tokens per target-model inference."""
        return self.tokens_per_round


@dataclasses.dataclass
class EngineState:
    """Device-side state of one decode batch, advanced by ``SpecEngine.step``.

    Treat it linearly: the jitted steps donate their cache/tree buffers, so a
    state consumed by step()/admit_slot()/release_slot() must not be reused —
    always thread the returned state forward (generate() and the serving
    runtime both do)."""

    tcache: Any  # target KV cache [U, B, S_max_t, ...]
    dcache: Any  # draft KV cache [U, B, S_max_d, ...]
    tr: Any  # stacked Tree, leaves [B, ...]
    plan: Any  # BatchPlan for the NEXT verification, leaves [B, ...]


@dataclasses.dataclass(frozen=True)
class StepResult:
    """Host-side outcome of one round, per batch row."""

    emitted: np.ndarray  # i32[B, bs+1] verified tokens (accepted + bonus)
    n_emitted: np.ndarray  # i32[B]
    n_accepted: np.ndarray  # i32[B]


def absorb_emitted(out: list, emitted_row, n_emitted: int, max_new: int, eos_id: int):
    """Append one row's verified tokens to ``out`` until EOS or ``max_new``.

    The single definition of truncation semantics (token appended first, then
    tested) shared by generate() and the serving runtime — the byte-identical
    serving contract depends on both paths stopping on exactly the same token.
    Returns (new_tokens, done)."""
    new = []
    for t in emitted_row[:n_emitted].tolist():
        out.append(int(t))
        new.append(int(t))
        if (eos_id >= 0 and t == eos_id) or len(out) >= max_new:
            return new, True
    return new, False


class SpecEngine:
    """Tree-based speculative decoding for attention architectures."""

    def __init__(self, target, draft, cfg: SpecConfig, S_max_t: int, S_max_d: int,
                 mesh_target=None, mesh_draft=None):
        self.target, self.draft, self.cfg = target, draft, cfg
        self.S_max_t, self.S_max_d = S_max_t, S_max_d
        self.mesh_target, self.mesh_draft = mesh_target, mesh_draft
        window = target.cfg.sliding_window
        c = cfg

        # ----- jitted draft-side steps ------------------------------------
        def expand(dparams, tr, dcache):
            leaf_ids, leaf_valid = jax.vmap(lambda t: T.select_leaves(t, c.w))(tr)
            tokens, rows, positions, mask, _ = jax.vmap(
                lambda t, li, lv: T.leaf_inputs(t, li, lv, S_max_d, draft.cfg.sliding_window)
            )(tr, leaf_ids, leaf_valid)
            logits, dcache = draft.spec_forward(dparams, dcache, tokens, positions, rows, mask)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32))
            top_lp, top_tok = jax.lax.top_k(lp, c.c)  # [B,w,c]
            tr = jax.vmap(T.insert_children)(tr, leaf_ids, leaf_valid, rows, top_tok, top_lp)
            return tr, dcache

        def select_plan(tr):
            return jax.vmap(lambda t: T.select_batch(t, c.bs, S_max_t, window))(tr)

        def reroot_fill(dparams, tr, dcache, node_ids, acc_pos, n_acc, bonus):
            tr, move, fill = jax.vmap(T.reroot)(tr, node_ids, acc_pos, n_acc, bonus)
            dcache = kvm.apply_moves(dcache, move.src, move.dst, move.mask)
            dcache = kvm.set_length(dcache, 0)  # length bookkeeping via tree.plen
            # fill missing prefix KV (accepted-but-unexpanded tokens)
            cols = jnp.arange(S_max_d, dtype=jnp.int32)
            fmask = (cols[None, None, :] <= fill.rows[:, :, None]) & fill.mask[:, :, None]
            _, dcache = draft.spec_forward(
                dparams, dcache, fill.tokens, fill.positions, fill.rows, fmask
            )
            return tr, dcache

        def seed(tr, root_tok, plen, root_logits):
            return jax.vmap(lambda t, tok, lg: T.seed_root(t, tok, plen, lg, c.c))(
                tr, root_tok, root_logits
            )

        # ----- jitted target-side steps -------------------------------------
        def verify(tparams, tcache, tokens, positions, rows, mask, parent_pos, valid):
            logits, tcache = self.target.spec_forward(tparams, tcache, tokens, positions, rows, mask)
            argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            acc_pos, n_acc, bonus, emitted, n_emitted = jax.vmap(T.verify_walk)(
                tokens, parent_pos, valid, argmax
            )
            # compact: accepted rows -> prefix  (target Fig.5 analogue)
            bs = tokens.shape[1]
            plen = rows[:, 0] + 1  # root row = plen-1
            src = jnp.where(acc_pos >= 0, jnp.take_along_axis(rows, jnp.maximum(acc_pos, 0), axis=1), -1)
            dst = plen[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]
            mmask = (jnp.arange(bs)[None, :] < n_acc[:, None]) & (src >= 0)
            tcache = kvm.apply_moves(tcache, src, dst, mmask)
            return acc_pos, n_acc, bonus, emitted, n_emitted, tcache

        self._expand = jax.jit(expand, donate_argnums=(1, 2))
        self._select_plan = jax.jit(select_plan)
        self._reroot_fill = jax.jit(reroot_fill, donate_argnums=(1, 2))
        self._seed = jax.jit(seed, static_argnums=(2,))
        self._verify = jax.jit(verify, donate_argnums=(1,))
        self._dprefill = jax.jit(lambda p, t, S: draft.prefill(p, tokens=t, S_max=S), static_argnums=(2,))
        self._tprefill = jax.jit(lambda p, t, S: target.prefill(p, tokens=t, S_max=S), static_argnums=(2,))
        # per-slot lifecycle (continuous batching); slot/plen are traced so
        # one compile covers every slot index and prompt length
        self._install = jax.jit(kvm.install_slot, donate_argnums=(0,))
        self._zero_slot = jax.jit(kvm.zero_slot, donate_argnums=(0,))
        self._reset_slot = jax.jit(T.reset_slot, donate_argnums=(0,))
        self._seed_slot = jax.jit(
            lambda tr, slot, tok, plen, lg: T.seed_slot(tr, slot, tok, plen, lg, c.c),
            donate_argnums=(0,),
        )

    # ------------------------------------------------------------------
    # state lifecycle (used by generate() below and by serving/runtime.py)
    # ------------------------------------------------------------------
    @property
    def grow_per_round(self) -> int:
        """Expansions needed to refill a re-rooted tree to >= bs nodes."""
        c = self.cfg
        return max(1, -(-(c.bs) // (c.w * c.c)))

    @property
    def plen_budget(self) -> int:
        """Largest per-row prefix length the caches can safely carry into one
        more round: verify rows reach plen-1+bs and the re-rooted tree needs
        another bs of headroom, so stop ``2*bs`` short of the tighter cache.

        The single definition of the KV-budget bound, shared by ``generate()``
        and the serving runtimes — if the two ever drift, a request near the
        budget stops at different tokens solo vs served, silently breaking the
        byte-identical contract."""
        return min(self.S_max_t, self.S_max_d) - 2 * self.cfg.bs

    def init_state(self, B: int) -> EngineState:
        """Empty B-slot serving state: zero caches, parked (invalid) trees.

        Parked slots are inert: their plans carry no valid node, so verify
        writes nothing and expand skips them; the runtime discards whatever
        they "emit"."""
        tcache = self.target.init_cache(B, self.S_max_t)
        dcache = self.draft.init_cache(B, self.S_max_d)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), T.init_tree(self.cfg.n_cap))
        with use_mesh(self.mesh_draft):
            plan = self._select_plan(tr)
        return EngineState(tcache, dcache, tr, plan)

    def _prefill_state(self, tparams, dparams, prompt) -> EngineState:
        """Whole-batch prefill + tree seed + initial growth (all rows start
        together — the generate() path)."""
        c = self.cfg
        B, P = prompt.shape
        with use_mesh(self.mesh_draft):
            dlogits, dcache = self._dprefill(dparams, jnp.asarray(prompt), self.S_max_d)
        with use_mesh(self.mesh_target):
            _, tcache = self._tprefill(tparams, jnp.asarray(prompt), self.S_max_t)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), T.init_tree(c.n_cap))
        root_tok = jnp.asarray(prompt[:, -1], jnp.int32)
        with use_mesh(self.mesh_draft):
            tr = self._seed(tr, root_tok, P, dlogits[:, -1, :])
            for _ in range(self.grow_per_round):
                tr, dcache = self._expand(dparams, tr, dcache)
            plan = self._select_plan(tr)
        return EngineState(tcache, dcache, tr, plan)

    def admit_slot(self, tparams, dparams, state: EngineState, slot: int, prompt) -> EngineState:
        """Admit one request into batch row ``slot`` of an in-flight state.

        The request is prefilled solo ([1, P] — byte-identical numerics to a
        solo generate() start), its cache rows installed into row ``slot`` of
        both serving caches, its tree re-seeded with its own prefix length,
        and the batch grown/re-planned so the next verify covers it.
        Neighboring rows' caches and trees are untouched (they only gain
        extra draft expansions, which never changes emitted tokens — the
        greedy-verification invariant)."""
        prompt = np.asarray(prompt, np.int32).reshape(1, -1)
        P = prompt.shape[1]
        with use_mesh(self.mesh_draft):
            dlogits, dcache1 = self._dprefill(dparams, jnp.asarray(prompt), self.S_max_d)
        with use_mesh(self.mesh_target):
            _, tcache1 = self._tprefill(tparams, jnp.asarray(prompt), self.S_max_t)
            tcache = self._install(state.tcache, tcache1, slot)
        with use_mesh(self.mesh_draft):
            dcache = self._install(state.dcache, dcache1, slot)
            tr = self._seed_slot(
                state.tr, slot, jnp.asarray(prompt[0, -1], jnp.int32),
                jnp.asarray(P, jnp.int32), dlogits[0, -1, :],
            )
            for _ in range(self.grow_per_round):
                tr, dcache = self._expand(dparams, tr, dcache)
            plan = self._select_plan(tr)
        return EngineState(tcache, dcache, tr, plan)

    def release_slot(self, state: EngineState, slot: int) -> EngineState:
        """Retire batch row ``slot``: park its tree and physically zero its
        KV rows in both caches, so no state can leak into the next occupant."""
        with use_mesh(self.mesh_target):
            tcache = self._zero_slot(state.tcache, slot)
        with use_mesh(self.mesh_draft):
            dcache = self._zero_slot(state.dcache, slot)
            tr = self._reset_slot(state.tr, slot)
            plan = self._select_plan(tr)
        return EngineState(tcache, dcache, tr, plan)

    def step(self, tparams, dparams, state: EngineState, stats: SpecStats | None = None,
             tracer=None, trace_track: str = "engine"):
        """One asynchronous round for every slot (the body of generate()):
        dispatch verification on the target group, concurrently expand the
        draft trees, sync the verified tokens to the host, then re-root /
        fill / grow / re-plan on the draft group.

        Returns (state', StepResult).  Rows at different decode depths
        coexist: all per-row quantities (prefix length, masks, acceptance)
        live in the vmapped tree, so the serving runtime can drive rows with
        mixed progress through the same jitted round.

        ``tracer`` (repro.obs) records the round's host-side phase spans —
        verify_dispatch / draft_expand / sync_emitted / reroot_grow — on
        ``trace_track`` (one track per serving replica); the default
        NULL_TRACER path is free."""
        c = self.cfg
        obs = tracer if tracer is not None else NULL_TRACER
        plan = self._bypass(state.plan) if c.draft_bypass else state.plan
        tr, dcache = state.tr, state.dcache
        draft_steps = 0
        # --- dispatch verification on the target group (async) -------------
        with obs.span("verify_dispatch", trace_track):
            with use_mesh(self.mesh_target):
                acc_pos, n_acc, bonus, emitted, n_emitted, tcache = self._verify(
                    tparams, state.tcache, plan.tokens, plan.positions, plan.rows,
                    plan.mask, plan.parent_pos, plan.valid,
                )
        # --- concurrently: d tree expansions on the draft group ------------
        if c.mode == "parallel":
            with obs.span("draft_expand", trace_track):
                with use_mesh(self.mesh_draft):
                    for _ in range(c.d):
                        tr, dcache = self._expand(dparams, tr, dcache)
                    draft_steps += c.d
        # --- sync point: verified tokens cross groups (host-mediated) ------
        with obs.span("sync_emitted", trace_track):
            # the round's ONE designated host sync: the verified-token
            # transfer (paper's NCCL exchange) — everything else stays async
            emitted_h = np.asarray(jax.device_get(emitted))  # repro: disable=HOTSYNC — designated sync point
            n_emitted_h = np.asarray(jax.device_get(n_emitted))  # repro: disable=HOTSYNC — designated sync point
            n_acc_h = np.asarray(jax.device_get(n_acc))  # repro: disable=HOTSYNC — designated sync point
        # --- re-root, fill, grow, select next batch (draft group) ----------
        with obs.span("reroot_grow", trace_track):
            with use_mesh(self.mesh_draft):
                tr, dcache = self._reroot_fill(dparams, tr, dcache, plan.node_ids, acc_pos, n_acc, bonus)
                n_grow = c.d if c.mode == "serial" else self.grow_per_round
                for _ in range(n_grow):
                    tr, dcache = self._expand(dparams, tr, dcache)
                draft_steps += n_grow
                new_plan = self._select_plan(tr)
        if stats is not None:
            stats.add_round(n_emitted_h, n_acc_h)
            stats.draft_steps += draft_steps
        return EngineState(tcache, dcache, tr, new_plan), StepResult(emitted_h, n_emitted_h, n_acc_h)

    # ---------------------------------------------------------------------
    def generate(self, tparams, dparams, prompt, max_new=None):
        """prompt: np.ndarray [B, P] int32. Returns (tokens [B, <=max_new] list, stats)."""
        c = self.cfg
        max_new = max_new or c.max_new
        B, P = prompt.shape
        t0 = monotonic()

        state = self._prefill_state(tparams, dparams, prompt)
        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        stats = SpecStats()
        rounds_cap = max_new + 2  # greedy emits >=1 token/round

        for _ in range(rounds_cap):
            longest = 0 if stats.emitted_rows is None else int(stats.emitted_rows.max())
            if done.all() or (P + longest) >= self.plen_budget:
                break
            state, res = self.step(tparams, dparams, state, stats=stats)
            for b in range(B):
                if not done[b]:
                    _, done[b] = absorb_emitted(
                        out[b], res.emitted[b], res.n_emitted[b], max_new, c.eos_id)

        stats.wall_s = monotonic() - t0
        return out, stats

    def profile(self, tparams, dparams, prompt, iters: int = 3):
        """Paper §5.5 profile pass: wall-time one draft expansion and one
        target verification (jits warmed first).  Returns ProfileResult."""
        from repro.core.scheduler import ProfileResult

        c = self.cfg
        B, P = prompt.shape
        with use_mesh(self.mesh_draft):
            dlogits, dcache = self._dprefill(dparams, jnp.asarray(prompt), self.S_max_d)
        with use_mesh(self.mesh_target):
            _, tcache = self._tprefill(tparams, jnp.asarray(prompt), self.S_max_t)
        t0tree = T.init_tree(c.n_cap)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), t0tree)
        with use_mesh(self.mesh_draft):
            tr = self._seed(tr, jnp.asarray(prompt[:, -1], jnp.int32), P, dlogits[:, -1, :])
            tr, dcache = self._expand(dparams, tr, dcache)  # warm
            plan = self._select_plan(tr)

        def draft_once():
            nonlocal tr, dcache
            with use_mesh(self.mesh_draft):
                tr, dcache = self._expand(dparams, tr, dcache)
                jax.block_until_ready(tr.tokens)

        def target_once():
            nonlocal tcache
            with use_mesh(self.mesh_target):
                out = self._verify(tparams, tcache, plan.tokens, plan.positions,
                                   plan.rows, plan.mask, plan.parent_pos, plan.valid)
                tcache = out[-1]
                jax.block_until_ready(out[0])

        target_once()  # warm
        t0 = monotonic()
        for _ in range(iters):
            draft_once()
        t_d = (monotonic() - t0) / iters
        t0 = monotonic()
        for _ in range(iters):
            target_once()
        t_t = (monotonic() - t0) / iters
        return ProfileResult(t_draft_s=t_d, t_target_s=t_t)

    def _bypass(self, plan):
        """Straggler mitigation: degenerate to root-only verification."""
        keep = jnp.arange(plan.tokens.shape[1]) == 0
        return T.BatchPlan(
            node_ids=plan.node_ids,
            tokens=plan.tokens,
            rows=jnp.where(keep[None, :], plan.rows, -1),
            positions=plan.positions,
            mask=plan.mask & keep[None, :, None],
            parent_pos=plan.parent_pos,
            valid=plan.valid & keep[None, :],
        )
