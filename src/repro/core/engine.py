"""SpecEngine — asynchronous, disaggregated speculative decoding (paper §3.1,
Algorithm 1, Figure 3).

The draft model lives on one device group (submesh), the target on another.
JAX's asynchronous dispatch makes the two jitted programs run concurrently on
disjoint device sets: the verify step for round n is enqueued first, then the
d draft-tree expansions for round n+1 are enqueued on the draft group; the
host blocks only on the tiny verified-token transfer (the paper's NCCL
exchange).  ``mode="serial"`` is the SwiftSpec-base baseline (expand, then
verify, no overlap).

Greedy-verification invariant: the emitted stream equals target-only greedy
decoding token-for-token (tests/test_engine.py).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kv as kvm
from repro.core import tree as T
from repro.sharding import use_mesh


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    bs: int = 8  # target verification batch (paper §5.5: 8)
    w: int = 4  # draft leaves expanded per step (paper §5.5: 8)
    c: int = 2  # children proposed per expanded leaf
    d: int = 3  # tree expansions per round (profiled: ~t_target/t_draft)
    n_cap: int = 64  # tree node capacity
    mode: str = "parallel"  # "parallel" | "serial"
    max_new: int = 64
    eos_id: int = -1  # -1: never stop early
    draft_bypass: bool = False  # straggler mitigation: verify root-only chain


@dataclasses.dataclass
class SpecStats:
    rounds: int = 0
    emitted: int = 0
    accepted: int = 0
    draft_steps: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_round(self) -> float:
        return self.emitted / max(self.rounds, 1)

    @property
    def compression_ratio(self) -> float:
        """Paper's metric: tokens per target-model inference."""
        return self.tokens_per_round


class SpecEngine:
    """Tree-based speculative decoding for attention architectures."""

    def __init__(self, target, draft, cfg: SpecConfig, S_max_t: int, S_max_d: int,
                 mesh_target=None, mesh_draft=None):
        self.target, self.draft, self.cfg = target, draft, cfg
        self.S_max_t, self.S_max_d = S_max_t, S_max_d
        self.mesh_target, self.mesh_draft = mesh_target, mesh_draft
        window = target.cfg.sliding_window
        c = cfg

        # ----- jitted draft-side steps ------------------------------------
        def expand(dparams, tr, dcache):
            leaf_ids, leaf_valid = jax.vmap(lambda t: T.select_leaves(t, c.w))(tr)
            tokens, rows, positions, mask, _ = jax.vmap(
                lambda t, li, lv: T.leaf_inputs(t, li, lv, S_max_d, draft.cfg.sliding_window)
            )(tr, leaf_ids, leaf_valid)
            logits, dcache = draft.spec_forward(dparams, dcache, tokens, positions, rows, mask)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32))
            top_lp, top_tok = jax.lax.top_k(lp, c.c)  # [B,w,c]
            tr = jax.vmap(T.insert_children)(tr, leaf_ids, leaf_valid, rows, top_tok, top_lp)
            return tr, dcache

        def select_plan(tr):
            return jax.vmap(lambda t: T.select_batch(t, c.bs, S_max_t, window))(tr)

        def reroot_fill(dparams, tr, dcache, node_ids, acc_pos, n_acc, bonus):
            tr, move, fill = jax.vmap(T.reroot)(tr, node_ids, acc_pos, n_acc, bonus)
            dcache = kvm.apply_moves(dcache, move.src, move.dst, move.mask)
            dcache = kvm.set_length(dcache, 0)  # length bookkeeping via tree.plen
            # fill missing prefix KV (accepted-but-unexpanded tokens)
            cols = jnp.arange(S_max_d, dtype=jnp.int32)
            fmask = (cols[None, None, :] <= fill.rows[:, :, None]) & fill.mask[:, :, None]
            _, dcache = draft.spec_forward(
                dparams, dcache, fill.tokens, fill.positions, fill.rows, fmask
            )
            return tr, dcache

        def seed(tr, root_tok, plen, root_logits):
            return jax.vmap(lambda t, tok, lg: T.seed_root(t, tok, plen, lg, c.c))(
                tr, root_tok, root_logits
            )

        # ----- jitted target-side steps -------------------------------------
        def verify(tparams, tcache, tokens, positions, rows, mask, parent_pos, valid):
            logits, tcache = self.target.spec_forward(tparams, tcache, tokens, positions, rows, mask)
            argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            acc_pos, n_acc, bonus, emitted, n_emitted = jax.vmap(T.verify_walk)(
                tokens, parent_pos, valid, argmax
            )
            # compact: accepted rows -> prefix  (target Fig.5 analogue)
            bs = tokens.shape[1]
            plen = rows[:, 0] + 1  # root row = plen-1
            src = jnp.where(acc_pos >= 0, jnp.take_along_axis(rows, jnp.maximum(acc_pos, 0), axis=1), -1)
            dst = plen[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]
            mmask = (jnp.arange(bs)[None, :] < n_acc[:, None]) & (src >= 0)
            tcache = kvm.apply_moves(tcache, src, dst, mmask)
            return acc_pos, n_acc, bonus, emitted, n_emitted, tcache

        self._expand = jax.jit(expand, donate_argnums=(1, 2))
        self._select_plan = jax.jit(select_plan)
        self._reroot_fill = jax.jit(reroot_fill, donate_argnums=(1, 2))
        self._seed = jax.jit(seed, static_argnums=(2,))
        self._verify = jax.jit(verify, donate_argnums=(1,))
        self._dprefill = jax.jit(lambda p, t, S: draft.prefill(p, tokens=t, S_max=S), static_argnums=(2,))
        self._tprefill = jax.jit(lambda p, t, S: target.prefill(p, tokens=t, S_max=S), static_argnums=(2,))

    # ---------------------------------------------------------------------
    def generate(self, tparams, dparams, prompt, max_new=None, collect_stats=True):
        """prompt: np.ndarray [B, P] int32. Returns (tokens [B, <=max_new] list, stats)."""
        c = self.cfg
        max_new = max_new or c.max_new
        B, P = prompt.shape
        t0 = time.perf_counter()

        with use_mesh(self.mesh_draft):
            dlogits, dcache = self._dprefill(dparams, jnp.asarray(prompt), self.S_max_d)
        with use_mesh(self.mesh_target):
            _, tcache = self._tprefill(tparams, jnp.asarray(prompt), self.S_max_t)

        t0tree = T.init_tree(c.n_cap)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), t0tree)
        root_tok = jnp.asarray(prompt[:, -1], jnp.int32)
        with use_mesh(self.mesh_draft):
            tr = self._seed(tr, root_tok, P, dlogits[:, -1, :])
            # initial growth to >= bs nodes
            g0 = max(1, -(-(c.bs) // (c.w * c.c)))
            for _ in range(g0):
                tr, dcache = self._expand(dparams, tr, dcache)
            plan = self._select_plan(tr)

        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        stats = SpecStats()
        rounds_cap = max_new + 2  # greedy emits >=1 token/round

        for _ in range(rounds_cap):
            if done.all() or (P + stats.emitted + 2 * c.bs) >= min(self.S_max_t, self.S_max_d):
                break
            if c.draft_bypass:
                plan = self._bypass(plan)
            # --- dispatch verification on the target group (async) ---------
            with use_mesh(self.mesh_target):
                acc_pos, n_acc, bonus, emitted, n_emitted, tcache = self._verify(
                    tparams, tcache, plan.tokens, plan.positions, plan.rows,
                    plan.mask, plan.parent_pos, plan.valid,
                )
            # --- concurrently: d tree expansions on the draft group --------
            if c.mode == "parallel":
                with use_mesh(self.mesh_draft):
                    for _ in range(c.d):
                        tr, dcache = self._expand(dparams, tr, dcache)
                    stats.draft_steps += c.d
            # --- sync point: verified tokens cross groups (host-mediated) --
            emitted_h = np.asarray(jax.device_get(emitted))
            n_emitted_h = np.asarray(jax.device_get(n_emitted))
            for b in range(B):
                if not done[b]:
                    toks = emitted_h[b, : n_emitted_h[b]].tolist()
                    for t in toks:
                        out[b].append(int(t))
                        if (c.eos_id >= 0 and t == c.eos_id) or len(out[b]) >= max_new:
                            done[b] = True
                            break
            stats.rounds += 1
            stats.emitted += int(n_emitted_h.sum()) // max(B, 1)
            stats.accepted += int(np.asarray(jax.device_get(n_acc)).sum()) // max(B, 1)

            # --- re-root, fill, grow, select next batch (draft group) ------
            with use_mesh(self.mesh_draft):
                tr, dcache = self._reroot_fill(dparams, tr, dcache, plan.node_ids, acc_pos, n_acc, bonus)
                n_grow = c.d if c.mode == "serial" else max(1, -(-(c.bs) // (c.w * c.c)))
                for _ in range(n_grow):
                    tr, dcache = self._expand(dparams, tr, dcache)
                stats.draft_steps += n_grow
                plan = self._select_plan(tr)

        stats.wall_s = time.perf_counter() - t0
        return out, stats

    def profile(self, tparams, dparams, prompt, iters: int = 3):
        """Paper §5.5 profile pass: wall-time one draft expansion and one
        target verification (jits warmed first).  Returns ProfileResult."""
        from repro.core.scheduler import ProfileResult

        c = self.cfg
        B, P = prompt.shape
        with use_mesh(self.mesh_draft):
            dlogits, dcache = self._dprefill(dparams, jnp.asarray(prompt), self.S_max_d)
        with use_mesh(self.mesh_target):
            _, tcache = self._tprefill(tparams, jnp.asarray(prompt), self.S_max_t)
        t0tree = T.init_tree(c.n_cap)
        tr = jax.tree.map(lambda x: jnp.stack([x] * B), t0tree)
        with use_mesh(self.mesh_draft):
            tr = self._seed(tr, jnp.asarray(prompt[:, -1], jnp.int32), P, dlogits[:, -1, :])
            tr, dcache = self._expand(dparams, tr, dcache)  # warm
            plan = self._select_plan(tr)

        def draft_once():
            nonlocal tr, dcache
            with use_mesh(self.mesh_draft):
                tr, dcache = self._expand(dparams, tr, dcache)
                jax.block_until_ready(tr.tokens)

        def target_once():
            nonlocal tcache
            with use_mesh(self.mesh_target):
                out = self._verify(tparams, tcache, plan.tokens, plan.positions,
                                   plan.rows, plan.mask, plan.parent_pos, plan.valid)
                tcache = out[-1]
                jax.block_until_ready(out[0])

        target_once()  # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            draft_once()
        t_d = (time.perf_counter() - t0) / iters
        t0 = time.perf_counter()
        for _ in range(iters):
            target_once()
        t_t = (time.perf_counter() - t0) / iters
        return ProfileResult(t_draft_s=t_d, t_target_s=t_t)

    def _bypass(self, plan):
        """Straggler mitigation: degenerate to root-only verification."""
        keep = jnp.arange(plan.tokens.shape[1]) == 0
        return T.BatchPlan(
            node_ids=plan.node_ids,
            tokens=plan.tokens,
            rows=jnp.where(keep[None, :], plan.rows, -1),
            positions=plan.positions,
            mask=plan.mask & keep[None, :, None],
            parent_pos=plan.parent_pos,
            valid=plan.valid & keep[None, :],
        )
