"""Chain-mode speculative decoding for recurrent-state architectures
(SSM / hybrid: rwkv6, zamba2) — DESIGN.md §6.

Tree speculation is inapplicable to a recurrent state: the tree's branches
cannot share one sequential state, and forking it per node costs
O(nodes × state).  We therefore speculate on *chains* (the paper's
sequence-based degenerate case, PEARL/AMUSD-style) while keeping the paper's
actual contribution — asynchronous, disaggregated draft/target execution —
fully intact:

  * the draft group autoregressively proposes k tokens from a snapshot of its
    recurrent state (the generation-time state advance is throwaway);
  * the target group verifies the whole chain in ONE chunked forward
    (``chain_forward`` with n_commit=0: logits are teacher-forced, the
    recurrent state is untouched), then commits exactly the accepted prefix —
    pure-attention targets commit for free (rows are already written; only
    ``len`` moves), state-bearing targets recompute from the pre-round cache;
  * draft-state consistency after partial acceptance is restored by
    *recompute-from-pre-state*: one chain forward of the accepted tokens on
    the snapshot;
  * in parallel mode the draft's next chain is generated concurrently with
    verification under the all-accepted assumption and is kept when the
    assumption holds (PEARL's reuse condition), else discarded.

Greedy-equality invariant: emitted tokens equal target-only greedy decoding
exactly (tests/test_chain_engine.py).  Single-request engine (B = 1), the
paper's latency regime; batch > 1 is served by replication.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.clock import monotonic
from repro.obs.trace import NULL_TRACER
from repro.sharding import use_mesh


@dataclasses.dataclass(frozen=True)
class ChainConfig:
    k: int = 6  # draft chain length per round
    mode: str = "parallel"  # "parallel" | "serial"
    max_new: int = 64
    eos_id: int = -1


@dataclasses.dataclass
class ChainStats:
    rounds: int = 0
    emitted: int = 0
    accepted: int = 0
    reused_chains: int = 0
    draft_chains: int = 0
    wall_s: float = 0.0

    @property
    def compression_ratio(self) -> float:
        return self.emitted / max(self.rounds, 1)


def _has_state(model) -> bool:
    return any(k in ("mamba2", "rwkv6") for k in model.cfg.layer_kinds)


class ChainSpecEngine:
    def __init__(self, target, draft, cfg: ChainConfig, S_max_t: int, S_max_d: int,
                 mesh_target=None, mesh_draft=None):
        self.target, self.draft, self.cfg = target, draft, cfg
        self.S_max_t, self.S_max_d = S_max_t, S_max_d
        self.mesh_target, self.mesh_draft = mesh_target, mesh_draft
        k = cfg.k

        def draft_chain(dparams, dcache, first_tok):
            """k greedy draft tokens; the advanced cache is returned for the
            full-acceptance reuse path (otherwise discarded)."""

            def step(carry, _):
                cache, tok = carry
                logits, cache = draft.decode_step(dparams, cache, tok, S_max_d)
                nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
                return (cache, nxt), nxt[:, 0]

            (dcache, _), toks = jax.lax.scan(step, (dcache, first_tok), None, length=k)
            return jnp.moveaxis(toks, 0, 1), dcache  # [B, k]

        def verify(tparams, tcache, u):
            """One target forward over the chain; no state commitment."""
            logits, tcache_rows = target.chain_forward(tparams, tcache, u, 0, S_max_t)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), tcache_rows

        self._draft_chain = jax.jit(draft_chain)
        self._verify = jax.jit(verify)
        self._tcommit = jax.jit(
            lambda tp, tc, u, n: target.chain_forward(tp, tc, u, n, S_max_t)[1]
        )
        self._dcommit = jax.jit(
            lambda dp, dc, u, n: draft.chain_forward(dp, dc, u, n, S_max_d)[1]
        )
        self._dprefill = jax.jit(lambda p, t, S: draft.prefill(p, tokens=t, S_max=S), static_argnums=(2,))
        self._tprefill = jax.jit(lambda p, t, S: target.prefill(p, tokens=t, S_max=S), static_argnums=(2,))

    # ------------------------------------------------------------------
    def session(self, tparams, dparams, *, tracer=None, track="chain") -> "ChainSession":
        """Bind params (+ optional tracer) into a ChainSession — the round
        API surface; mirrors ``SpecEngine.session``."""
        return ChainSession(self, tparams, dparams,
                            tracer=tracer or NULL_TRACER, track=track)

    def generate(self, tparams, dparams, prompt, max_new=None):
        warnings.warn(
            "ChainSpecEngine.generate(tparams, dparams, prompt) is deprecated; "
            "use ChainSpecEngine.session(tparams, dparams).generate(prompt)",
            DeprecationWarning, stacklevel=2)
        return self.session(tparams, dparams).generate(prompt, max_new=max_new)


@dataclasses.dataclass
class ChainSession:
    """Params bound to a ChainSpecEngine — the chain-mode analogue of
    ``EngineSession``.  ``generate`` emits the same phase-span vocabulary as
    the tree engine (``verify_dispatch`` held open across the concurrent
    next-chain speculation, ``draft_lookahead``, ONE fused ``sync_emitted``
    host transfer per round, ``reroot_grow`` for the state commit), so chain
    rounds land in the same ``phase_breakdown`` and the same HOTSYNC budget:
    one designated sync point per round."""

    engine: ChainSpecEngine
    tparams: Any
    dparams: Any
    tracer: Any = NULL_TRACER
    track: str = "chain"

    def generate(self, prompt, max_new=None):
        eng = self.engine
        tparams, dparams = self.tparams, self.dparams
        c = eng.cfg
        k = c.k
        max_new = max_new or c.max_new
        B, P = prompt.shape
        assert B == 1, "chain engine is per-request (paper's latency regime)"
        t0 = monotonic()

        with use_mesh(eng.mesh_target):
            tlogits, tcache = eng._tprefill(tparams, jnp.asarray(prompt), eng.S_max_t)
        with use_mesh(eng.mesh_draft):
            _, dcache = eng._dprefill(dparams, jnp.asarray(prompt), eng.S_max_d)

        pending = jnp.argmax(tlogits[:, -1, :], -1).astype(jnp.int32)[:, None]  # [1,1]
        out = [int(pending[0, 0])]
        stats = ChainStats(emitted=1)
        t_state = _has_state(eng.target)
        pre_drafts = None  # speculated next chain (parallel reuse)
        done = (c.eos_id >= 0 and out[0] == c.eos_id) or len(out) >= max_new

        while not done:
            if (P + stats.emitted + 2 * k + 2) >= min(eng.S_max_t, eng.S_max_d):
                break
            rspan = self.tracer.begin("round", self.track)
            dsnap = dcache  # pre-round draft state (functional: snapshot is free)

            # --- draft chain -------------------------------------------------
            with self.tracer.span("draft_expand", self.track):
                with use_mesh(eng.mesh_draft):
                    if pre_drafts is not None:
                        drafts, dfull_cache = pre_drafts
                        stats.reused_chains += 1
                    else:
                        drafts, _ = eng._draft_chain(dparams, dcache, pending)
                        dfull_cache = None
                        stats.draft_chains += 1
                u = jnp.concatenate([pending, drafts[:, : k - 1]], axis=1)  # [1,k]

            # --- target verification: the span stays open until the verified
            # tokens land at the sync point — it IS the verify window the
            # concurrent speculation below overlaps with
            vspan = self.tracer.begin("verify_dispatch", self.track)
            with use_mesh(eng.mesh_target):
                argmax, tcache_rows = eng._verify(tparams, tcache, u)

            # --- concurrently: speculate the next chain ----------------------
            next_pre = None
            if c.mode == "parallel":
                with self.tracer.span("draft_lookahead", self.track):
                    with use_mesh(eng.mesh_draft):
                        dfull = eng._dcommit(dparams, dsnap, u, jnp.asarray(k))
                        nxt_drafts, nxt_cache = eng._draft_chain(
                            dparams, dfull, drafts[:, k - 1:])
                        next_pre = (nxt_drafts, None)
                        stats.draft_chains += 1

            # --- sync point ---------------------------------------------------
            with self.tracer.span("sync_emitted", self.track):
                argmax_h, drafts_h = jax.device_get((argmax, drafts))  # repro: disable=HOTSYNC — designated sync point: ONE fused transfer of the round's verdict
            vspan.end()
            argmax_h = np.asarray(argmax_h)[0]  # [k]
            drafts_h = np.asarray(drafts_h)[0]  # [k]
            n_acc = 0
            while n_acc < k - 1 and drafts_h[n_acc] == argmax_h[n_acc]:
                n_acc += 1
            n_emit = n_acc + 1

            for t in argmax_h[:n_emit].tolist():
                out.append(int(t))
                if (c.eos_id >= 0 and t == c.eos_id) or len(out) >= max_new:
                    done = True
                    break
            stats.rounds += 1
            stats.accepted += n_acc
            stats.emitted += n_emit

            full = (n_acc == k - 1) and (argmax_h[k - 1] == drafts_h[k - 1])
            pending = jnp.asarray([[int(argmax_h[n_emit - 1])]], jnp.int32)

            # --- commit accepted prefix ---------------------------------------
            with self.tracer.span("reroot_grow", self.track):
                n = jnp.asarray(n_emit)
                with use_mesh(eng.mesh_target):
                    if t_state:
                        tcache = eng._tcommit(tparams, tcache, u, n)
                    else:  # attention-only: rows already written, just move len
                        tcache = {**tcache_rows, "len": tcache_rows["len"] + n}
                with use_mesh(eng.mesh_draft):
                    if full and c.mode == "parallel":
                        dcache = dfull  # chain fully accepted: snapshot+u == truth
                        pre_drafts = (nxt_drafts, None)
                    else:
                        dcache = eng._dcommit(dparams, dsnap, u, n)
                        pre_drafts = None
            rspan.end()

        stats.wall_s = monotonic() - t0
        return [out[:max_new]], stats
