"""Substrate units: checkpoint atomicity/resume, optimizer math, schedules,
gradient compression, data determinism, fault retry, scheduler policy."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt import CheckpointManager
from repro.core.scheduler import (AllocationResult, ProfileResult, candidate_depths,
                                  sweep_allocation)
from repro.data import DataConfig, SyntheticLMDataset
from repro.optim import (adamw_init, adamw_update, compress_int8, decompress_int8,
                         warmup_cosine)
from repro.runtime import FaultConfig, StragglerPolicy, retry_step


# -------------------------------------------------------------- checkpoints

def test_ckpt_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        state = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
        for s in (3, 7, 9):
            cm.save(s, state, blocking=True)
        assert cm.all_steps() == [7, 9]  # GC keeps 2
        s, restored = cm.restore_latest(state)
        assert s == 9
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5.0))
        assert restored["b"]["c"].dtype == jnp.bfloat16


def test_ckpt_async_then_wait():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=3)
        state = {"w": jnp.zeros((128, 128))}
        cm.save(1, state, blocking=False)
        cm.wait()
        assert cm.latest_step() == 1


def test_ckpt_ignores_partial_writes():
    """A crash mid-write (temp dir, no MANIFEST) must be invisible."""
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=3)
        cm.save(5, {"x": jnp.ones(3)}, blocking=True)
        os.makedirs(os.path.join(d, "step_000000000009.tmp-dead"), exist_ok=True)
        broken = os.path.join(d, "step_000000000010")
        os.makedirs(broken, exist_ok=True)  # no MANIFEST -> invalid
        assert cm.latest_step() == 5
        s, _ = cm.restore_latest({"x": jnp.ones(3)})
        assert s == 5


def test_ckpt_resume_is_bit_exact():
    """Train 6 steps vs train 3 + restore + 3: identical parameters (the
    fault-tolerance contract, with the deterministic data pipeline)."""
    from repro.configs.base import ModelConfig
    from repro.launch.steps import make_train_step
    from repro.models.api import make_model

    cfg = ModelConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64)
    m = make_model(cfg)
    ds = SyntheticLMDataset(DataConfig(cfg.vocab_size, 16, 2, seed=3))
    step = jax.jit(make_train_step(cfg, m))

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            params, opt, _ = step(params, opt, {"tokens": jnp.asarray(ds.batch(s)["tokens"])})
        return params, opt

    p0 = m.init(jax.random.PRNGKey(0))
    o0 = adamw_init(p0)
    pa, oa = run(p0, o0, 0, 6)

    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        pb, ob = run(p0, o0, 0, 3)
        cm.save(2, (pb, ob), blocking=True)
        s, (pr, orr) = cm.restore_latest((pb, ob))
        pc, oc = run(pr, orr, 3, 6)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------------------- optimizer

def test_adamw_matches_reference_step():
    params = {"w": jnp.full((4,), 2.0)}
    st_ = adamw_init(params)
    g = {"w": jnp.full((4,), 0.5)}
    lr = 0.1
    new_p, st2 = adamw_update(g, st_, params, lr, b1=0.9, b2=0.95, eps=1e-8,
                              weight_decay=0.0, grad_clip=1e9)
    # step 1: mhat = g, vhat = g^2 -> update = lr * g/|g| = lr
    np.testing.assert_allclose(np.asarray(new_p["w"]), 2.0 - lr, rtol=1e-5)
    assert int(st2.step) == 1


def test_adamw_grad_clip():
    params = {"w": jnp.zeros((3,))}
    st_ = adamw_init(params)
    g = {"w": jnp.full((3,), 100.0)}
    _, st2 = adamw_update(g, st_, params, 0.1, grad_clip=1.0)
    gnorm_clipped = float(jnp.sqrt(jnp.sum(jnp.square(st2.mu["w"])))) / 0.1
    assert gnorm_clipped <= 1.0 + 1e-4


def test_warmup_cosine_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10, total_steps=100)) for s in range(100)]
    assert lr[0] == 0.0 and abs(lr[10] - 1.0) < 0.11
    assert lr[99] < lr[50] < lr[10]
    assert lr[99] >= 0.1 - 1e-6  # final_frac floor


# -------------------------------------------------------------- compression

@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_compression_error_bound(seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(257,)), jnp.float32)
    q, s = compress_int8(x)
    err = np.max(np.abs(np.asarray(decompress_int8(q, s) - x)))
    assert err <= float(s) / 2 + 1e-7  # half-ulp of the int8 grid


# -------------------------------------------------------------- data

def test_data_deterministic_and_step_indexed():
    ds = SyntheticLMDataset(DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=1))
    a, b = ds.batch(5)["tokens"], ds.batch(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(ds.batch(5)["tokens"], ds.batch(6)["tokens"])
    assert a.shape == (4, 17) and a.dtype == np.int32


def test_data_is_learnable_markov():
    """The stream must be peaky (predictable) for spec-decoding realism."""
    ds = SyntheticLMDataset(DataConfig(vocab_size=50, seq_len=64, global_batch=8, seed=0))
    toks = ds.batch(0)["tokens"]
    # successor entropy is low: most-frequent successor of each state dominates
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    tops = [c.most_common(1)[0][1] / sum(c.values()) for c in succ.values() if sum(c.values()) >= 5]
    assert np.mean(tops) > 0.5


# -------------------------------------------------------------- fault / sched

def test_retry_step_recovers():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return 42

    assert retry_step(flaky, FaultConfig(backoff_s=0.001)) == 42
    assert len(calls) == 3


def test_retry_step_gives_up():
    def always():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        retry_step(always, FaultConfig(max_retries=2, backoff_s=0.001))


def test_straggler_policy():
    sp = StragglerPolicy(t_draft_profiled_s=0.01, deadline_ratio=2.0)
    sp.observe(0.015)
    assert not sp.should_bypass()
    sp.observe(0.05)
    assert sp.should_bypass()


def test_candidate_depths_and_allocation():
    assert candidate_depths(ProfileResult(t_draft_s=3e-3, t_target_s=10e-3)) == (3, 4)
    assert candidate_depths(ProfileResult(t_draft_s=10e-3, t_target_s=3e-3)) == (1, 2)
    res = sweep_allocation(8, lambda nt, nd: -abs(nt - 6))
    assert (res.n_target, res.n_draft) == (6, 2)
