"""Clock contract + stats aggregation seams the tracer depends on.

``WallClock``/``VirtualClock`` are the serving timeline: ``reset()``
re-zeros it, ``on_round()`` advances only the virtual flavor, and
``wait_until()`` never moves time backwards.  ``percentile``/``summary``/
``merge_summary`` must stay honest on empty or unstamped inputs (nan, not a
1e-9-floor fantasy throughput), and fleet occupancy is weighted by rounds
so an idle replica cannot skew the number.
"""

import time

import numpy as np

from repro.serving import ServerStats, VirtualClock, WallClock, merge_summary
from repro.serving.stats import fleet_report, percentile


# ---------------------------------------------------------------------------
# clock contract
# ---------------------------------------------------------------------------


def test_wallclock_monotonic_and_reset():
    c = WallClock()
    t0 = c.now()
    assert t0 >= 0.0
    time.sleep(0.01)
    assert c.now() > t0
    c.reset()  # re-zeros the timeline (run() calls this once)
    assert c.now() < t0 + 0.01


def test_wallclock_on_round_is_passive():
    """Real time advances by itself: on_round must not jump the clock."""
    c = WallClock()
    before = c.now()
    c.on_round()
    assert c.now() - before < 0.5  # no artificial jump, just elapsed time


def test_wallclock_wait_until():
    c = WallClock()
    c.wait_until(c.now() - 5.0)  # the past: returns immediately, no sleep
    target = c.now() + 0.02
    c.wait_until(target)
    assert c.now() >= target


def test_virtualclock_contract():
    c = VirtualClock(round_dt=0.25)
    assert c.now() == 0.0
    c.on_round()
    c.on_round()
    assert c.now() == 0.5
    c.wait_until(2.0)  # idle jump forward
    assert c.now() == 2.0
    c.wait_until(1.0)  # never backwards
    assert c.now() == 2.0
    c.reset()
    assert c.now() == 0.0
    assert VirtualClock().round_dt == 1.0


# ---------------------------------------------------------------------------
# percentile / summary guards
# ---------------------------------------------------------------------------


def test_percentile_empty_is_nan():
    assert np.isnan(percentile([], 50))
    assert percentile([3.0], 50) == 3.0
    assert percentile([1.0, 3.0], 100) == 3.0


def test_summary_unstamped_window_is_nan_not_nonsense():
    """A missed reset()/run() leaves started_s == finished_s == 0.0; the old
    1e-9 floor reported trillions of tok/s.  Now: nan, rendered '-'."""
    st = ServerStats()
    st.on_admit(0, 0, 0.0, 0.0)
    st.on_tokens(0, 3, 2, 0.5)
    st.on_finish(0, 0.5)
    s = st.summary()
    assert s["n_finished"] == 1 and s["total_tokens"] == 3
    assert np.isnan(s["throughput_tok_s"])
    rep = st.report()
    assert " - tok/s" in rep and "nan tok/s" not in rep

    st.started_s, st.finished_s = 0.0, 2.0  # stamped: finite again
    assert st.summary()["throughput_tok_s"] == 1.5
    assert "1.5 tok/s" in st.report()


def test_merge_summary_unstamped_is_nan():
    st = ServerStats()
    s = merge_summary([st])
    assert np.isnan(s["throughput_tok_s"])
    assert " - tok/s" in fleet_report([st])


# ---------------------------------------------------------------------------
# fleet occupancy weighting
# ---------------------------------------------------------------------------


def _stats_with(rounds: int, occ: int) -> ServerStats:
    st = ServerStats()
    for _ in range(rounds):
        st.on_round(occ, 0)
    return st


def test_merge_summary_occupancy_weighted_by_rounds():
    """A replica that only spun 1 round must not average 50/50 against one
    that sustained occupancy 2 for 9 rounds."""
    busy, idle = _stats_with(9, 2), _stats_with(1, 0)
    s = merge_summary([busy, idle])
    assert s["mean_occupancy"] == (2 * 9 + 0 * 1) / 10  # 1.8, not 1.0
    assert s["per_replica_occupancy"] == [2.0, 0.0]
    assert s["per_replica_rounds"] == [9, 1]

    # an all-idle fleet (zero rounds anywhere) reports 0.0, not nan
    assert merge_summary([ServerStats(), ServerStats()])["mean_occupancy"] == 0.0
    # equal rounds degenerate to the plain mean
    s = merge_summary([_stats_with(4, 2), _stats_with(4, 1)])
    assert s["mean_occupancy"] == 1.5
