"""input_specs coverage: every (arch × applicable shape) cell builds its step
function and ShapeDtypeStruct stand-ins without touching devices (the cheap
half of the dry-run; lower+compile runs in launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, SHAPES, cell_applicable, get_config
from repro.launch.specs import batch_specs, cache_specs, cell_specs, dryrun_config


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_cell_specs_build(arch, shape, mesh):
    ok, why = cell_applicable(get_config(arch), SHAPES[shape])
    if not ok:
        pytest.skip(why)
    step, args, meta = cell_specs(arch, shape, mesh)
    assert callable(step)
    leaves = jax.tree.leaves(args)
    assert leaves, "no inputs?"
    for leaf in leaves:
        assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert leaf.sharding is not None
    assert meta["arch"] == arch


def test_applicability_matrix():
    """long_500k runs exactly for the sub-quadratic archs (DESIGN.md §6)."""
    runs = {a for a in ASSIGNED if cell_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"zamba2-2.7b", "rwkv6-7b"}
    for a in ASSIGNED:  # all other shapes apply everywhere
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_applicable(get_config(a), SHAPES[s])[0]


def test_decode_cache_specs_sharded(mesh):
    cfg = dryrun_config("qwen2.5-14b", mesh)
    cache = cache_specs(cfg, mesh, B=8, S_max=64)
    leaves = jax.tree.leaves(cache)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    # k/v leaves are [U, B, S, hkv, hd]
    shapes = {l.shape for l in leaves if l.ndim == 5}
    assert (48, 8, 64, 8, 128) in shapes


def test_stub_frontend_specs(mesh):
    """Audio arch gets embeds+labels; vlm gets tokens+enc (assignment stubs)."""
    m_cfg = dryrun_config("musicgen-large", mesh)
    b = batch_specs(m_cfg, SHAPES["train_4k"], mesh)
    assert set(b) == {"embeds", "labels"}
    v_cfg = dryrun_config("llama-3.2-vision-90b", mesh)
    b2 = batch_specs(v_cfg, SHAPES["train_4k"], mesh)
    assert set(b2) == {"tokens", "enc"}
    assert b2["enc"].shape == (256, 1024, 8192)
