"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; the same calls compile to Mosaic on TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.quant.awq import dequantize, quantize_groupwise

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# -----------------------------------------------------------------------------
# tree attention
# -----------------------------------------------------------------------------

TREE_SHAPES = [
    # (B, n, Hq, Hkv, hd, S)
    (2, 4, 8, 2, 64, 96),     # GQA g=4
    (1, 8, 4, 4, 32, 128),    # MHA
    (2, 3, 6, 3, 80, 200),    # odd hd / S (exercises padding)
    (1, 16, 8, 1, 128, 256),  # MQA (granite-style kv=1)
    (3, 1, 4, 2, 128, 64),    # single query (decode-like)
]


@pytest.mark.parametrize("shape", TREE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_attention_sweep(shape, dtype):
    B, n, hq, hkv, hd, S = shape
    q = _rand((B, n, hq, hd), dtype)
    k = _rand((B, S, hkv, hd), dtype)
    v = _rand((B, S, hkv, hd), dtype)
    mask = jnp.asarray(RNG.random((B, n, S)) < 0.5)
    mask = mask.at[:, 0, :].set(False)  # fully-masked row -> zeros
    out = ops.tree_attention(q, k, v, mask)
    want = ref.tree_attention_ref(q, k, v, mask)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    assert (np.asarray(out)[:, 0] == 0).all()


def test_tree_attention_block_sizes():
    B, n, hq, hkv, hd, S = 1, 4, 4, 2, 64, 384
    q, k, v = _rand((B, n, hq, hd)), _rand((B, S, hkv, hd)), _rand((B, S, hkv, hd))
    mask = jnp.asarray(RNG.random((B, n, S)) < 0.7)
    want = ref.tree_attention_ref(q, k, v, mask)
    for bk in (128, 256):
        out = ops.tree_attention(q, k, v, mask, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


# -----------------------------------------------------------------------------
# decode attention (split-KV single kernel)
# -----------------------------------------------------------------------------

DECODE_SHAPES = [(2, 8, 2, 64, 160), (3, 4, 4, 48, 100), (1, 32, 8, 128, 512), (2, 4, 1, 64, 96)]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
def test_decode_attention_sweep(shape):
    B, hq, hkv, hd, S = shape
    q = _rand((B, hq, hd))
    k = _rand((B, S, hkv, hd))
    v = _rand((B, S, hkv, hd))
    length = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    out = ops.decode_attention(q, k, v, length)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_tree_attention():
    """The split-KV decode kernel is the length-masked special case."""
    B, hq, hkv, hd, S = 2, 8, 4, 64, 192
    q, k, v = _rand((B, hq, hd)), _rand((B, S, hkv, hd)), _rand((B, S, hkv, hd))
    length = jnp.asarray([64, 100], jnp.int32)
    mask = jnp.arange(S)[None, None, :] < length[:, None, None]
    a = ops.decode_attention(q, k, v, length)
    b = ops.tree_attention(q[:, None], k, v, mask)[:, 0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


# -----------------------------------------------------------------------------
# fused SwiGLU
# -----------------------------------------------------------------------------


@given(st.sampled_from([(8, 64, 128), (100, 96, 200), (1, 256, 64), (130, 128, 384)]),
       st.sampled_from(["float32", "bfloat16"]))
@settings(max_examples=8, deadline=None)
def test_fused_swiglu(shape, dtype):
    T, d, ff = shape
    dt = jnp.dtype(dtype)
    x = _rand((T, d), dt)
    wg = _rand((d, ff), dt, 0.1)
    wu = _rand((d, ff), dt, 0.1)
    out = ops.fused_swiglu(x, wg, wu)
    want = ref.fused_swiglu_ref(x, wg, wu)
    tol = 1e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# -----------------------------------------------------------------------------
# int4 AWQ dequant-GEMM
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 256, 96), (32, 128, 300), (5, 384, 128)])
def test_int4_matmul(shape):
    T, K, N = shape
    g = 128
    x = _rand((T, K))
    w = _rand((K, N), scale=0.05)
    qd = quantize_groupwise(w, g)
    out = ops.int4_matmul(x, qd.qweight, qd.scales, qd.zeros, group_size=g)
    want = x @ dequantize(qd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_int4_quant_error_bounded():
    """Groupwise 4-bit: max reconstruction error <= scale/2 per element."""
    w = _rand((256, 64), scale=0.1)
    qd = quantize_groupwise(w, 128)
    err = np.abs(np.asarray(dequantize(qd) - w))
    smax = np.repeat(np.asarray(qd.scales), 128, axis=0)
    assert (err <= smax / 2 + 1e-6).all()
