"""Observability layer (repro.obs): tracer, metrics registry, phase
breakdown, and the instrumented serving stack.

The two load-bearing contracts:
  * disabled tracing is FREE — no-op spans are a cached singleton and the
    per-round hot path allocates nothing (the overhead regression test);
  * enabled tracing explains the round — the phase spans recorded during a
    real continuous-batching run cover >= 95% of every round's wall time,
    so the draft/verify/absorb decomposition is evidence, not guesswork.
"""

import json
import tracemalloc

import numpy as np
import pytest

from repro.core.engine import SpecConfig, SpecEngine
from repro.obs import (
    MetricsRegistry,
    NOOP_SPAN,
    NULL_TRACER,
    Tracer,
    breakdown_report,
    phase_breakdown,
)
from repro.obs.metrics import Histogram, Series
from repro.serving import (
    ContinuousBatchingRuntime,
    Request,
    ShardedServingRuntime,
    VirtualClock,
    merge_summary,
)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


class FakeTime:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def test_span_lifecycle_and_ring_buffer():
    ft = FakeTime()
    tr = Tracer(capacity=4, clock=ft)
    s = tr.begin("a", "t0")
    ft.advance(0.5)
    s.end()
    assert [x.name for x in tr.spans()] == ["a"]
    assert tr.spans()[0].dur == pytest.approx(0.5)
    s.end()  # idempotent: a second end neither re-stamps nor re-records
    assert len(tr.spans()) == 1 and tr.spans()[0].dur == pytest.approx(0.5)

    with tr.span("b", "t0", args={"k": 1}) as sp:
        ft.advance(0.25)
        sp.set("extra", 2)
    assert tr.spans("b")[0].args == {"k": 1, "extra": 2}

    for i in range(6):  # overflow the ring: oldest drop, counted
        with tr.span(f"s{i}"):
            ft.advance(0.1)
    assert len(tr.spans()) == 4
    assert tr.dropped == 4  # a, b, s0, s1 fell out
    assert [x.name for x in tr.spans()] == ["s2", "s3", "s4", "s5"]


def test_chrome_and_jsonl_export():
    ft = FakeTime()
    tr = Tracer(clock=ft)
    with tr.span("round", "replica0"):
        ft.advance(0.002)
    tr.instant("evt", "router")
    tr.counter("queue_depth", 3)
    doc = tr.to_chrome()
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"round", "evt", "queue_depth", "thread_name"} <= names
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == pytest.approx(0.0) and x["dur"] == pytest.approx(2000.0)
    meta = {e["args"]["name"]: e["tid"] for e in evs if e["ph"] == "M"}
    assert meta.keys() == {"replica0", "router", "counters"}
    assert x["tid"] == meta["replica0"]
    c = next(e for e in evs if e["ph"] == "C")
    assert c["args"] == {"queue_depth": 3}
    json.dumps(doc)  # serializable as-is

    line = tr.to_jsonl().strip()
    rec = json.loads(line)
    assert rec == {"name": "round", "track": "replica0", "t0": 0.0,
                   "t1": pytest.approx(0.002), "dur": pytest.approx(0.002)}


def test_write_picks_format_from_extension(tmp_path):
    tr = Tracer()
    with tr.span("x"):
        pass
    p1 = tr.write(str(tmp_path / "trace.json"))
    assert "traceEvents" in json.load(open(p1))
    p2 = tr.write(str(tmp_path / "trace.jsonl"))
    assert json.loads(open(p2).read().splitlines()[0])["name"] == "x"


# ---------------------------------------------------------------------------
# the overhead regression: disabled tracing is free
# ---------------------------------------------------------------------------


def test_disabled_tracer_noop_singleton_zero_allocation():
    """The disabled per-round path returns ONE cached object and allocates
    nothing — adding instrument points must never tax an untraced server."""
    tr = Tracer(enabled=False)
    assert tr.begin("round") is NOOP_SPAN
    assert tr.span("absorb", "replica0") is NOOP_SPAN
    assert NULL_TRACER.begin("x") is NOOP_SPAN

    def per_round():
        s = tr.begin("round", "replica0")
        with tr.span("verify_dispatch", "replica0"):
            pass
        with tr.span("absorb", "replica0"):
            pass
        tr.counter("queue_depth", 1)
        tr.instant("evt")
        s.set("k", 1)
        s.end()

    import repro.obs.trace as trace_mod

    obs_dir = trace_mod.__file__.rsplit("/", 1)[0]
    tracemalloc.start()
    try:
        for _ in range(100):  # absorb one-time warmup (caches, interning)
            per_round()
        snap1 = tracemalloc.take_snapshot()
        for _ in range(1000):
            per_round()
        snap2 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    grown = [s for s in snap2.compare_to(snap1, "lineno")
             if s.size_diff > 0 and s.traceback[0].filename.startswith(obs_dir)]
    leaked = sum(s.size_diff for s in grown)
    # CPython caches one "zombie frame" per function (~113 B, constant); a
    # real per-round allocation would be >= 16 KiB over 1000 rounds
    assert leaked < 2048, f"disabled tracer allocated over 1000 rounds: {grown}"
    assert len(tr.spans()) == 0 and tr.dropped == 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_handles_are_get_or_create():
    m = MetricsRegistry()
    c = m.counter("rounds", replica="0")
    assert m.counter("rounds", replica="0") is c
    assert m.counter("rounds", replica="1") is not c
    c.inc()
    c.inc(2)
    assert c.value == 3
    g = m.gauge("occ")
    g.set(0.5)
    snap = m.snapshot()
    assert {"name": "rounds", "labels": {"replica": "0"}, "value": 3.0} in snap["counters"]
    assert snap["gauges"] == [{"name": "occ", "labels": {}, "value": 0.5}]


def test_histogram_buckets_sum_count():
    h = Histogram(buckets=(0, 1, 2, 4))
    for x in (0, 1, 1, 3, 99):
        h.observe(x)
    assert h.counts == [1, 2, 0, 1, 1]  # le=0,1,2,4,+Inf (non-cumulative)
    assert h.count == 5 and h.sum == 104.0
    assert h.mean == pytest.approx(20.8)
    with pytest.raises(ValueError):
        Histogram(buckets=(2, 1))
    with pytest.raises(ValueError):
        Histogram(buckets=())


def test_series_is_bounded():
    s = Series(maxlen=3)
    for i in range(5):
        s.append(float(i), i * 10)
    assert s.values() == [20, 30, 40] and s.dropped == 2 and s.last == 40


def test_prometheus_text_format():
    m = MetricsRegistry()
    m.counter("serving_rounds_total", replica="0").inc(7)
    h = m.histogram("serving_accept_depth", buckets=(0, 1, 2), replica="0")
    for x in (0, 1, 1, 5):
        h.observe(x)
    m.series("serving_queue_depth").append(0.0, 4)
    text = m.to_prometheus()
    assert '# TYPE serving_rounds_total counter' in text
    assert 'serving_rounds_total{replica="0"} 7' in text
    # histogram buckets are CUMULATIVE with an +Inf bucket, plus _sum/_count
    assert 'serving_accept_depth_bucket{le="0",replica="0"} 1' in text
    assert 'serving_accept_depth_bucket{le="1",replica="0"} 3' in text
    assert 'serving_accept_depth_bucket{le="+Inf",replica="0"} 4' in text
    assert 'serving_accept_depth_sum{replica="0"} 7' in text
    assert 'serving_accept_depth_count{replica="0"} 4' in text
    assert 'serving_queue_depth 4' in text


def test_metrics_write_json_and_prom(tmp_path):
    m = MetricsRegistry()
    m.counter("c").inc()
    p = m.write(str(tmp_path / "m.json"), extra={"phase_breakdown": {"x": 1}})
    doc = json.load(open(p))
    assert doc["phase_breakdown"] == {"x": 1} and doc["counters"][0]["name"] == "c"
    p = m.write(str(tmp_path / "m.prom"))
    assert "# TYPE c counter" in open(p).read()


# ---------------------------------------------------------------------------
# phase breakdown
# ---------------------------------------------------------------------------


def _round(tr, ft, track, phases, gap=0.0):
    r = tr.begin("round", track)
    for name, dt in phases:
        with tr.span(name, track):
            ft.advance(dt)
    ft.advance(gap)
    r.end()


def test_phase_breakdown_synthetic():
    ft = FakeTime()
    tr = Tracer(clock=ft)
    phases = [("verify_dispatch", 0.2), ("draft_expand", 0.3),
              ("sync_emitted", 0.1), ("reroot_grow", 0.25), ("absorb", 0.1)]
    _round(tr, ft, "replica0", phases, gap=0.05)  # covered 0.95 of 1.0
    _round(tr, ft, "replica0", phases, gap=0.0)   # covered 1.0 of 0.95

    bd = phase_breakdown(tr)
    assert bd["n_rounds"] == 2
    assert bd["round_total_s"] == pytest.approx(1.95)
    assert bd["phase_s"]["draft_expand"] == pytest.approx(0.6)
    assert bd["draft_s"] == pytest.approx(1.1)    # expand + reroot_grow
    assert bd["verify_s"] == pytest.approx(0.6)   # dispatch + sync
    assert bd["absorb_s"] == pytest.approx(0.2)
    assert bd["draft_frac"] == pytest.approx(1.1 / 1.95)
    assert bd["coverage_min"] == pytest.approx(0.95)
    assert bd["coverage_mean"] == pytest.approx((0.95 + 1.0) / 2)
    rep = breakdown_report(bd)
    assert "draft" in rep and "2 rounds" in rep


def test_phase_breakdown_ignores_nested_and_foreign_spans():
    """Only the five top-level phases count: a ``retire`` nested inside
    ``absorb`` (or admit spans between rounds) must not double-count
    coverage, and another track's phases never leak across."""
    ft = FakeTime()
    tr = Tracer(clock=ft)
    with tr.span("admit_prefill", "replica0"):
        ft.advance(0.3)
    r = tr.begin("round", "replica0")
    with tr.span("verify_dispatch", "replica0"):
        ft.advance(0.5)
    with tr.span("absorb", "replica0"):
        with tr.span("retire", "replica0"):
            ft.advance(0.2)
        ft.advance(0.3)
    r.end()
    # a concurrent round on another track with its own phases
    _round(tr, ft, "replica1", [("draft_expand", 0.4)])
    bd = phase_breakdown(tr)
    assert bd["n_rounds"] == 2
    assert bd["coverage_min"] <= 1.0 and bd["coverage_mean"] <= 1.0
    assert bd["phase_s"]["verify_dispatch"] == pytest.approx(0.5)
    assert bd["phase_s"]["absorb"] == pytest.approx(0.5)
    assert bd["phase_s"]["draft_expand"] == pytest.approx(0.4)


def test_phase_breakdown_empty_is_nan_marked():
    """Zero rounds must read as 'unknown' (nan), never as an instantaneous
    round with perfect-zero coverage — a dead tracer that reported 0.0s
    rounds would slide straight past the CI coverage gate."""
    bd = phase_breakdown(Tracer())
    assert bd["n_rounds"] == 0 and bd["round_total_s"] == 0.0
    assert np.isnan(bd["mean_round_s"])
    assert np.isnan(bd["coverage_mean"]) and np.isnan(bd["coverage_min"])
    assert all(np.isnan(v) for v in bd["phase_frac"].values())
    for group in ("draft", "verify", "absorb"):
        assert bd[f"{group}_s"] == 0.0 and np.isnan(bd[f"{group}_frac"])
    assert breakdown_report(bd) == "phase breakdown: no rounds traced"


def test_merge_summary_no_replicas_is_nan_marked():
    """merge_summary([]) — a fleet that never started — must not divide by
    zero and must nan-mark the rate fields rather than report 0 tok/s."""
    s = merge_summary([])
    assert s["n_replicas"] == 0 and s["n_finished"] == 0
    assert np.isnan(s["throughput_tok_s"])
    assert np.isnan(s["ttft_p50_s"]) and np.isnan(s["ttft_p99_s"])
    assert s["mean_occupancy"] == 0.0 and s["mean_acceptance"] == 0.0


# ---------------------------------------------------------------------------
# the instrumented serving stack, end to end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def obs_engine(dense_pair):
    T, D, tp, dp = dense_pair
    cfg = SpecConfig(bs=8, w=4, c=2, d=2, n_cap=64, mode="parallel", max_new=24)
    return SpecEngine(T, D, cfg, S_max_t=256, S_max_d=256), tp, dp


def _prompt(k, P=8):
    return ((np.arange(1, P + 1) * k + 3) % 128).astype(np.int32)


def test_traced_continuous_run_covers_rounds(obs_engine):
    """The acceptance contract: a traced serving run produces round spans
    whose draft/verify/absorb children explain >= 95% of each round, and a
    metrics snapshot with the accept-depth histogram, per-replica round
    counters, queue-depth samples, and TTFT observations."""
    eng, tp, dp = obs_engine
    tracer, metrics = Tracer(), MetricsRegistry()
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=2, clock=VirtualClock(),
                                   tracer=tracer, metrics=metrics)
    reqs = [Request(rid=i, prompt=_prompt(i + 1, P=8 + 4 * (i % 2)),
                    arrival_s=0.7 * i, max_new=12) for i in range(4)]
    assert rt.submit_trace(reqs) == 4
    results = rt.run()
    assert sorted(results) == [0, 1, 2, 3]

    # --- spans: every engine round traced, phases cover the round wall time
    rounds = tracer.spans("round")
    assert len(rounds) == rt.stats.rounds
    bd = phase_breakdown(tracer)
    assert bd["n_rounds"] == rt.stats.rounds
    assert bd["coverage_min"] >= 0.95, breakdown_report(bd)
    for phase in ("verify_dispatch", "draft_expand", "sync_emitted",
                  "reroot_grow", "absorb"):
        assert bd["phase_s"][phase] > 0.0, f"phase {phase} never recorded"
    # admission + routing instrumented too
    assert len(tracer.spans("admit_prefill")) == 4
    assert len(tracer.spans("retire")) == 4
    routes = [s for s in tracer.spans("route") if s.args]
    assert {s.args["rid"] for s in routes} == {0, 1, 2, 3}
    assert len(tracer.counters("queue_depth")) == rt.stats.rounds

    # --- metrics: the snapshot the adaptive-depth work will read
    assert metrics.counter("serving_rounds_total", replica="0").value == rt.stats.rounds
    assert metrics.counter("serving_admitted_total", replica="0").value == 4
    assert metrics.counter("serving_finished_total", replica="0").value == 4
    total_tokens = sum(len(v) for v in results.values())
    assert metrics.counter("serving_tokens_total", replica="0").value == total_tokens
    h = metrics.histogram("serving_accept_depth", replica="0")
    assert h.count == sum(r.n_rounds for r in rt.stats.records.values())
    assert h.sum == sum(r.n_accepted for r in rt.stats.records.values())
    ttft = metrics.histogram("serving_ttft_seconds", replica="0")
    assert ttft.count == 4
    q = metrics.series("serving_queue_depth")
    assert len(q.samples) == rt.stats.rounds
    occ = metrics.series("serving_occupancy", replica="0")
    assert [int(v) for v in occ.values()] == rt.stats.occupancy_samples


def test_untraced_run_is_unchanged(obs_engine):
    """Default construction (no tracer) still serves identically and keeps
    metrics, with zero spans recorded anywhere."""
    eng, tp, dp = obs_engine
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=VirtualClock())
    rt.submit(Request(rid=0, prompt=_prompt(5), max_new=8))
    results = rt.run()
    solo, _ = eng.generate(tp, dp, _prompt(5).reshape(1, -1), max_new=8)
    assert results[0] == solo[0]
    assert rt.tracer is NULL_TRACER and len(NULL_TRACER.spans()) == 0
    assert rt.metrics.counter("serving_finished_total", replica="0").value == 1


def test_sharded_metrics_per_replica_labels(obs_engine):
    """Two replicas: spans land on separate tracks and metrics carry the
    owning replica's label, so the fleet view decomposes."""
    eng, tp, dp = obs_engine
    tracer, metrics = Tracer(), MetricsRegistry()
    rt = ShardedServingRuntime([eng, eng], tp, dp, n_slots=1,
                               clock=VirtualClock(), tracer=tracer,
                               metrics=metrics)
    reqs = [Request(rid=i, prompt=_prompt(3 + i), arrival_s=0.0, max_new=6)
            for i in range(2)]
    rt.submit_trace(reqs)
    rt.run()
    tracks = {s.track for s in tracer.spans("round")}
    assert tracks == {"replica0", "replica1"}
    for i in (0, 1):
        assert metrics.counter("serving_admitted_total", replica=str(i)).value == 1
        assert metrics.counter("serving_rounds_total",
                               replica=str(i)).value == rt.steppers[i].stats.rounds
    snap = metrics.snapshot()
    fam = [c for c in snap["counters"] if c["name"] == "serving_rounds_total"]
    assert {c["labels"]["replica"] for c in fam} == {"0", "1"}
