"""Deterministic micro-subset of ``hypothesis``, installed by conftest.py when
the real package is absent (it is an optional test dep, pinned in
requirements-test.txt).

Only the surface the test suite actually uses is provided: ``given``,
``settings``, and the strategies ``integers``, ``booleans``, ``floats``,
``sampled_from``, ``lists``, ``data``.  Example generation is seeded purely by
the example index, so a failing example reproduces exactly across runs — the
property the suite relies on hypothesis for.  Shrinking, the example database,
and stateful testing are intentionally out of scope.
"""

from __future__ import annotations

import functools
import inspect
import types

import numpy as np

_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def _floats(min_value=0.0, max_value=1.0, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def _lists(elements, min_size=0, max_size=None):
    hi = min_size + 8 if max_size is None else max_size

    def draw(rng):
        n = int(rng.integers(min_size, hi + 1))
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


class _Data:
    """Interactive draws (``st.data()``): share the example's rng stream."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.draw(self._rng)


def _data():
    return _Strategy(lambda rng: _Data(rng))


def settings(max_examples=50, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            for ex in range(n):
                rng = np.random.default_rng([_SEED, ex])
                vals = [s.draw(rng) for s in strategies]
                fn(*args, *vals, **kwargs)

        # like hypothesis, strategies fill the trailing parameters; only the
        # leading ones (pytest fixtures) stay visible to the test collector
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        runner.__signature__ = sig.replace(parameters=params[: len(params) - len(strategies)])
        del runner.__wrapped__  # keep inspect off the original signature
        runner.is_hypothesis_test = True
        return runner

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.booleans = _booleans
strategies.floats = _floats
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.data = _data
