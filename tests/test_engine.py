"""The central system test (paper correctness contract): with greedy
verification, speculative output equals target-only greedy decoding exactly —
serial AND parallel (asynchronous, disaggregated) modes, any draft."""

import jax
import numpy as np
import pytest

from conftest import greedy_reference
from repro.configs import get_config
from repro.core.engine import SpecConfig, SpecEngine
from repro.models.api import make_model


def _run(T, D, tp, dp, mode, prompt, max_new=24, **kw):
    cfg = SpecConfig(bs=8, w=4, c=2, d=2, n_cap=64, mode=mode, max_new=max_new, **kw)
    eng = SpecEngine(T, D, cfg, S_max_t=256, S_max_d=256)
    return eng.generate(tp, dp, prompt)


@pytest.mark.parametrize("mode", ["serial", "parallel"])
def test_greedy_equality_independent_draft(dense_pair, mode):
    T, D, tp, dp = dense_pair
    prompt = (np.arange(1, 9, dtype=np.int32) % 128).reshape(1, 8)
    ref = greedy_reference(T, tp, prompt, 24)
    out, stats = _run(T, D, tp, dp, mode, prompt)
    assert out[0] == ref[0]
    assert stats.rounds > 0 and stats.emitted >= 24


@pytest.mark.parametrize("mode", ["serial", "parallel"])
def test_greedy_equality_self_draft(dense_pair, mode):
    """draft == target: high acceptance, deep chains — stresses re-rooting."""
    T, _, tp, _ = dense_pair
    prompt = (np.arange(3, 11, dtype=np.int32) % 128).reshape(1, 8)
    ref = greedy_reference(T, tp, prompt, 32)
    out, stats = _run(T, T, tp, tp, mode, prompt, max_new=32)
    assert out[0] == ref[0]
    assert stats.compression_ratio > 1.2  # peaked logits -> real acceptance


def test_greedy_equality_batched(dense_pair):
    T, D, tp, dp = dense_pair
    prompt = (np.arange(16, dtype=np.int32).reshape(2, 8) * 3 + 1) % 128
    ref = greedy_reference(T, tp, prompt, 16)
    out, _ = _run(T, D, tp, dp, "parallel", prompt, max_new=16)
    assert out == ref


def test_compression_parallel_close_to_serial(dense_pair):
    """Paper Table 6: parallel trades a little compression (~9%) for overlap;
    assert the parallel ratio stays within 50% of serial (qualitative)."""
    T, _, tp, _ = dense_pair
    prompt = (np.arange(5, 13, dtype=np.int32) % 128).reshape(1, 8)
    _, st_serial = _run(T, T, tp, tp, "serial", prompt, max_new=32)
    _, st_par = _run(T, T, tp, tp, "parallel", prompt, max_new=32)
    assert st_par.compression_ratio > 0.5 * st_serial.compression_ratio


def test_draft_bypass_still_exact(dense_pair):
    """Straggler mitigation degrades to ~autoregressive but stays exact."""
    T, D, tp, dp = dense_pair
    prompt = (np.arange(2, 10, dtype=np.int32) % 128).reshape(1, 8)
    ref = greedy_reference(T, tp, prompt, 12)
    out, stats = _run(T, D, tp, dp, "parallel", prompt, max_new=12, draft_bypass=True)
    assert out[0] == ref[0]


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "minicpm3-4b", "deepseek-moe-16b"])
def test_greedy_equality_arch_families(arch):
    """Tree spec holds across MoE and MLA attention variants (smoke configs)."""
    cfg = get_config(arch, smoke=True)
    T = make_model(cfg)
    tp = T.init(jax.random.PRNGKey(0))
    tp["lm_head"].value = tp["lm_head"].value * 4.0
    prompt = (np.arange(1, 7, dtype=np.int32) % cfg.vocab_size).reshape(1, 6)
    ref = greedy_reference(T, tp, prompt, 12)
    out, _ = _run(T, T, tp, tp, "parallel", prompt, max_new=12)
    assert out[0] == ref[0]
