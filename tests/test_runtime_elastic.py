"""Elastic re-sharding + serving-mesh helpers (runtime/elastic.py)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_serving_mesh
from repro.models.api import make_model
from repro.runtime.elastic import reshard_params, submeshes
from repro.sharding import unbox


def test_submeshes_single_device_fallback():
    tgt, drf = submeshes(jax.devices(), n_target=1)
    assert tgt.devices.size >= 1 and drf.devices.size >= 1


def test_make_serving_mesh_fallback():
    tgt, drf = make_serving_mesh(6, 2)  # 1 CPU device -> shared mesh
    assert "model" in tgt.axis_names and "model" in drf.axis_names


def test_make_serving_mesh_replicas():
    """replicas=N returns N (target, draft) pairs; with too few devices every
    pair falls back to the shared single-device mesh (correctness-only), and
    replicas=1 keeps the historical 2-tuple signature."""
    import pytest

    pairs = make_serving_mesh(6, 2, replicas=2)
    assert isinstance(pairs, list) and len(pairs) == 2
    for tgt, drf in pairs:
        assert "model" in tgt.axis_names and "model" in drf.axis_names
        assert tgt.devices.size == 1 and drf.devices.size == 1  # CPU fallback
    single = make_serving_mesh(6, 2, replicas=1)
    assert isinstance(single, tuple) and len(single) == 2
    with pytest.raises(ValueError):
        make_serving_mesh(6, 2, replicas=0)
    # partial fit (enough devices for one replica, not all) must raise, not
    # silently overlap later replicas onto device 0: on this 1-device host a
    # 1-device group fits once but not twice
    with pytest.raises(ValueError):
        make_serving_mesh(1, 0, replicas=2)


def test_reshard_params_preserves_values():
    cfg = get_config("qwen2.5-14b", smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    vals = reshard_params(params, mesh)
    for a, b in zip(jax.tree.leaves(unbox(params)), jax.tree.leaves(vals)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_then_forward_matches():
    """A re-sharded model (elastic draft/target re-allocation) computes the
    same logits — the invariant that makes reallocation transparent."""
    from repro.sharding import Param, use_mesh
    import jax.tree_util as jtu

    cfg = get_config("qwen2.5-14b", smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = (jnp.arange(12, dtype=jnp.int32).reshape(1, 12) * 3 + 1) % cfg.vocab_size
    ref = np.asarray(m.forward_train(params, tokens=toks), np.float32)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    vals = reshard_params(params, mesh)
    boxed_leaves, treedef = jtu.tree_flatten(params, is_leaf=lambda x: isinstance(x, Param))
    reboxed = jtu.tree_unflatten(
        treedef, [Param(v, p.axes) for v, p in zip(jtu.tree_leaves(vals), boxed_leaves)]
    )
    with use_mesh(mesh):
        out = np.asarray(m.forward_train(reboxed, tokens=toks), np.float32)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
