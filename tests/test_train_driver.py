"""End-to-end training driver: loss improves on the synthetic Markov stream,
and checkpoint auto-resume continues identically (deliverable (b))."""

import tempfile

import numpy as np

from repro.launch.train import main as train_main


def test_train_loss_improves_and_resumes():
    with tempfile.TemporaryDirectory() as d:
        first, last = train_main([
            "--arch", "qwen2.5-14b", "--steps", "60", "--batch", "4", "--seq", "64",
            "--lr", "3e-3", "--ckpt", d, "--ckpt-every", "25", "--log-every", "30",
        ])
        assert last < first * 0.9, f"loss did not improve: {first} -> {last}"

        # resume: picks up from the saved step and finishes without error
        f2, l2 = train_main([
            "--arch", "qwen2.5-14b", "--steps", "70", "--batch", "4", "--seq", "64",
            "--lr", "3e-3", "--ckpt", d, "--ckpt-every", "1000", "--log-every", "30",
        ])
        assert np.isfinite(l2)
