"""Loop-aware HLO cost model: exact agreement with XLA on loop-free modules,
trip-scaling on (nested) scans, collective accounting under SPMD."""

import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.launch.hlo_parse import analyze, compiled_cost as _cost


def _compiled(f, *avals):
    return jax.jit(f).lower(*avals).compile()


def test_matches_xla_on_loop_free():
    c = _compiled(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((256, 256), jnp.float32),
                  jax.ShapeDtypeStruct((256, 256), jnp.float32))
    mc = analyze(c.as_text())
    assert mc.flops == _cost(c)["flops"] == 2 * 256**3
    assert mc.bytes_raw == _cost(c)["bytes accessed"]


def test_scan_trip_scaling():
    def f(x, w):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=8)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 128), jnp.float32))
    mc = analyze(c.as_text())
    assert mc.flops == 8 * 2 * 128**3
    assert list(mc.loop_trips.values()) == [8]
    # XLA's own aggregate counts the body once — document the gap we fix
    # (± a few scalar flops from the loop counter)
    assert abs(_cost(c)["flops"] - 2 * 128**3) < 100


def test_nested_scan_trip_product():
    def g(x, w):
        def outer(c, _):
            y, _ = jax.lax.scan(lambda cc, __: (cc @ w, None), c, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = _compiled(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mc = analyze(c.as_text())
    assert mc.flops == 12 * 2 * 64**3
    assert sorted(mc.loop_trips.values()) == [3, 4]


_SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_parse import analyze

mesh = jax.make_mesh((4,), ("model",))
x = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=NamedSharding(mesh, P(None, "model")))
w = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=NamedSharding(mesh, P("model", None)))

def f(x, w):
    y = x @ w  # contraction over the sharded dim -> all-reduce
    return y

c = jax.jit(f, out_shardings=NamedSharding(mesh, P(None, None))).lower(x, w).compile()
mc = analyze(c.as_text())
assert sum(mc.collective_count.values()) >= 1, mc.collective_count
# all-reduce of the f32 [64,64] partial product: 16 KiB raw operand
assert abs(mc.collective_bytes_raw - 64*64*4) < 1e-6, mc.collective_raw
print("SPMD_PARSE_OK")
"""


def test_collectives_under_spmd_subprocess():
    r = subprocess.run([sys.executable, "-c", _SPMD_SCRIPT], capture_output=True,
                       text=True, timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "SPMD_PARSE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
