"""HOTSYNC good fixture: designated sync suppressed, cold paths ignored."""

import jax
import numpy as np


class ToyServingRuntime:
    def run(self, x):
        emitted = np.asarray(jax.device_get(x))  # repro: disable=HOTSYNC — the round's one designated sync point
        return emitted

    def report(self, x):
        return jax.device_get(x)  # cold path: `report` is not a hot scope
