"""RETRACE bad fixture: one instance of every hazard class the rule names.

Never imported — scanned by tests/test_analysis.py only.
"""

import functools

import jax
import numpy as np


@jax.jit
def decorated(x):
    return np.sum(x)  # host numpy inside the traced body


@functools.partial(jax.jit, static_argnums=(1,))
def partial_jitted(x, opts=[]):  # mutable default on a static arg
    return x


def local(x):
    return float(x) + x.item()  # scalar coercion + concretizing method


wrapped = jax.jit(local)

inline = jax.jit(lambda x: int(x))
