"""PALLAS good fixture: guarded grid, matching arities, no input writes."""

import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def good_call(x, block_m):
    m = x.shape[0]
    if m % block_m:
        raise ValueError(f"M={m} must be a multiple of block_m={block_m}")
    grid = (m // block_m,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
