"""PALLAS good fixture: guarded grid, matching arities, no unaliased input writes."""

import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def good_call(x, block_m):
    m = x.shape[0]
    if m % block_m:
        raise ValueError(f"M={m} must be a multiple of block_m={block_m}")
    grid = (m // block_m,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _inplace_kernel(x_ref, o_ref):
    # writing the input ref is sanctioned here: it is aliased onto the output
    x_ref[...] = x_ref[...] * 2.0
    o_ref[...] = x_ref[...]


def good_aliased_inplace(x, block_m):
    """Input-ref write WITH input_output_aliases declared — must stay clean
    (the donating kv_move_rows pattern, docs/kernels.md)."""
    m = x.shape[0]
    if m % block_m:
        raise ValueError(f"M={m} must be a multiple of block_m={block_m}")
    grid = (m // block_m,)
    return pl.pallas_call(
        _inplace_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={0: 0},
    )(x)
