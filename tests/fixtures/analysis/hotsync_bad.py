"""HOTSYNC bad fixture: stray syncs inside a hot-scope round method."""

import jax
import jax.numpy as jnp


class ToyServingRuntime:
    def run(self, x):
        out = jax.device_get(x)  # stray host sync in the round loop
        x.block_until_ready()  # stalls async dispatch
        if jnp.any(x > 0):  # implicit __bool__ — a blocking transfer
            return out
        return None
