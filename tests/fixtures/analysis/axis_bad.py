"""AXIS bad fixture: typo'd axis names in every checked position."""

import jax
from jax.sharding import PartitionSpec as P


def specs():
    return P("modle", None), P(("data", "pdo"))


def collective(x):
    return jax.lax.psum(x, "mdoel")


def mesh(devs):
    return jax.sharding.Mesh(devs, ("data", "modell"))


def logical(constrain, x):
    return constrain(x, "batch", "embedd")
