"""CLOCK bad fixture: raw reads as calls, via alias, and as a reference."""

import time
from time import perf_counter as pc


def stamp():
    return time.time()


def lap():
    return pc()


DEFAULT_CLOCK = time.perf_counter  # passing the reference is the same bypass
