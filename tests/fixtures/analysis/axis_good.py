"""AXIS good fixture: only declared mesh/logical axis names."""

import jax
from jax.sharding import PartitionSpec as P


def specs():
    return P("model", None), P(("data", "model"))


def collective(x):
    return jax.lax.psum(x, "model"), jax.lax.all_gather(x, "data")


def mesh(devs):
    return jax.sharding.Mesh(devs, ("data", "model"))


def logical(constrain, x):
    return constrain(x, "batch", "embed")
