"""PALLAS bad fixture: index_map arity, block rank, input write, bare //."""

import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    x_ref[...] = o_ref[...] * 2.0  # writes an INPUT ref, no alias declared
    o_ref[...] = x_ref[...]


def bad_call(x, block_m):
    m = x.shape[0]
    grid = (m // block_m,)  # unguarded floor division
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m,), lambda i, j: (i,))],  # 2 args, rank-1 grid
        out_specs=pl.BlockSpec((block_m,), lambda i: (i, 0)),  # 2 idx, rank-1 block
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
