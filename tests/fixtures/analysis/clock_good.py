"""CLOCK good fixture: sleeping is allowed, timestamps come from a clock."""

import time


def nap(seconds):
    time.sleep(seconds)  # spends time, does not read it


def stamp(clock):
    return clock()  # injected Clock — the sanctioned path
