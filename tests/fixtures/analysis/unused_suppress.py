"""Unused-suppression fixture: the escape matches nothing and is reported."""

X = 1  # repro: disable=CLOCK — nothing on this line violates CLOCK
