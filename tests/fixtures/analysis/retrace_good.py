"""RETRACE good fixture: jnp inside jit, numpy outside, hashable statics."""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def decorated(x):
    b = x.shape[0]  # shapes are python ints at trace time — fine
    return jnp.sum(x.reshape(b, -1), axis=-1)


@functools.partial(jax.jit, static_argnums=(1,))
def partial_jitted(x, n=4):  # hashable static default
    return x * n


def host_side(x):
    return np.sum(x)  # numpy OUTSIDE any jitted function is fine


wrapped = jax.jit(decorated)
