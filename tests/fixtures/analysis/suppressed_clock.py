"""Suppression fixture: both comment placements silence the finding."""

import time

T0 = time.time()  # repro: disable=CLOCK — fixture: same-line form

# repro: disable=CLOCK — fixture: standalone line directly above
T1 = time.time()
