"""repro.analysis: the static-analysis pass that guards the hot decode round.

Contracts under test:
  * each rule trips on its bad fixture (and ONLY its rule trips) and stays
    silent on the matching good fixture;
  * suppressions silence findings in both comment placements, and an unused
    suppression is itself a finding;
  * the baseline round-trips, deleting an entry resurfaces its finding, and
    an entry matching nothing is stale (fails the run);
  * the shipped src/ tree is clean modulo the checked-in baseline, and
    removing any escape (suppression comment or baseline entry) flips the
    exit code — the self-clean acceptance gate.
"""

import json
import os
import re

import pytest

from repro.analysis import rules as _rules  # noqa: F401 — populates REGISTRY
from repro.analysis.baseline import apply_baseline, load_baseline, write_baseline
from repro.analysis.cli import main
from repro.analysis.core import REGISTRY, analyze_file
from repro.analysis.project import ProjectContext, build_project_context

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "fixtures", "analysis")
REPO = os.path.dirname(HERE)
SRC = os.path.join(REPO, "src")
BASELINE = os.path.join(REPO, "analysis-baseline.json")

# discovered by fixture naming convention: <rule>_bad.py / <rule>_good.py,
# so adding a rule + its fixtures auto-enrolls it in the contract tests
RULES = tuple(sorted(
    f[:-len("_bad.py")] for f in os.listdir(FIXTURES) if f.endswith("_bad.py")))


def _scan(name, project=None):
    return analyze_file(os.path.join(FIXTURES, name), FIXTURES,
                        project or ProjectContext())


# ---------------------------------------------------------------------------
# per-rule fixtures
# ---------------------------------------------------------------------------


def test_registry_has_all_five_rules():
    assert {"RETRACE", "AXIS", "PALLAS", "CLOCK", "HOTSYNC"} <= set(REGISTRY)
    assert {r.upper() for r in RULES} <= set(REGISTRY)  # fixture <-> rule pairing


@pytest.mark.parametrize("rule", RULES)
def test_bad_fixture_trips_exactly_its_rule(rule):
    findings = _scan(f"{rule}_bad.py")
    assert findings, f"{rule}_bad.py produced no findings"
    assert {f.rule for f in findings} == {rule.upper()}


@pytest.mark.parametrize("rule", RULES)
def test_good_fixture_is_clean(rule):
    assert _scan(f"{rule}_good.py") == []


def test_retrace_covers_every_hazard_class():
    msgs = " | ".join(f.message for f in _scan("retrace_bad.py"))
    assert "host numpy call" in msgs
    assert "`float()`" in msgs and "`int()`" in msgs  # decorated + lambda
    assert "`.item()`" in msgs
    assert "mutable" in msgs  # static arg with unhashable default


def test_axis_suggests_the_closest_declared_name():
    findings = _scan("axis_bad.py")
    assert len(findings) == 5
    hints = [f.message for f in findings if "did you mean" in f.message]
    assert any("'model'" in h for h in hints)
    assert any("'embed'" in h for h in hints)


def test_pallas_covers_every_consistency_check():
    msgs = " | ".join(f.message for f in _scan("pallas_bad.py"))
    assert "index_map takes 2 arg(s)" in msgs  # vs rank-1 grid
    assert "returns 2 indices" in msgs  # vs rank-1 block shape
    assert "writes input ref" in msgs
    assert "floor-division grid" in msgs


def test_hotsync_covers_every_sync_shape():
    msgs = " | ".join(f.message for f in _scan("hotsync_bad.py"))
    assert "jax.device_get" in msgs
    assert "block_until_ready" in msgs
    assert "__bool__" in msgs


def test_clock_flags_references_not_just_calls():
    findings = _scan("clock_bad.py")
    assert len(findings) == 3  # time.time(), aliased pc(), bare reference
    assert any("time.perf_counter" in f.message for f in findings)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_suppression_both_placements_silence_the_finding():
    assert _scan("suppressed_clock.py") == []


def test_unused_suppression_is_reported():
    findings = _scan("unused_suppress.py")
    assert [f.rule for f in findings] == ["UNUSED-SUPPRESS"]


def test_parse_failure_is_a_finding_not_a_crash(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def broken(:\n")
    findings = analyze_file(str(p), str(tmp_path), ProjectContext())
    assert [f.rule for f in findings] == ["PARSE"]


# ---------------------------------------------------------------------------
# fingerprints + baseline
# ---------------------------------------------------------------------------


def test_fingerprint_survives_line_drift(tmp_path):
    src = open(os.path.join(FIXTURES, "clock_bad.py"), encoding="utf-8").read()
    a = tmp_path / "a"
    b = tmp_path / "b"
    a.mkdir(), b.mkdir()
    (a / "m.py").write_text(src)
    (b / "m.py").write_text("# padding\n# padding\n\n" + src)
    fa = analyze_file(str(a / "m.py"), str(a), ProjectContext())
    fb = analyze_file(str(b / "m.py"), str(b), ProjectContext())
    assert [f.fingerprint for f in fa] == [f.fingerprint for f in fb]
    assert [f.line for f in fa] != [f.line for f in fb]  # drift really happened


def test_baseline_roundtrip_delete_and_stale(tmp_path):
    findings = _scan("clock_bad.py")
    path = str(tmp_path / "bl.json")
    assert write_baseline(path, findings, "fixture grandfather") == len(findings)
    baseline = load_baseline(path)

    flagged, stale = apply_baseline(findings, baseline)
    assert stale == [] and all(f.baselined for f in flagged)

    # deleting one entry resurfaces exactly that finding as new
    victim = findings[0].fingerprint
    del baseline[victim]
    flagged, stale = apply_baseline(findings, baseline)
    assert stale == []
    assert [f.fingerprint for f in flagged if not f.baselined] == [victim]

    # an entry matching no finding is stale — it must fail the run
    baseline["deadbeefdeadbeef#0"] = "covered code is gone"
    _, stale = apply_baseline(findings, baseline)
    assert stale == ["deadbeefdeadbeef#0"]


# ---------------------------------------------------------------------------
# project context: the axis vocabulary really comes from the repo
# ---------------------------------------------------------------------------


def test_project_context_extracts_repo_axes():
    ctx = build_project_context([SRC])
    assert ctx.rules_file and ctx.rules_file.endswith("rules.py")
    assert ctx.mesh_file and ctx.mesh_file.endswith("mesh.py")
    assert {"model", "data"} <= ctx.mesh_axes
    assert {"batch", "embed"} <= ctx.logical_axes


# ---------------------------------------------------------------------------
# CLI: exit codes, JSON report, the self-clean gate over src/
# ---------------------------------------------------------------------------


def test_cli_exit_codes(tmp_path, capsys):
    good = os.path.join(FIXTURES, "clock_good.py")
    bad = os.path.join(FIXTURES, "clock_bad.py")
    assert main([good, "--no-baseline"]) == 0
    assert main([bad, "--no-baseline"]) == 1
    assert main([str(tmp_path / "empty-nothing-here"), "--no-baseline"]) == 2
    assert main([bad, "--rules", "NOSUCHRULE"]) == 2
    capsys.readouterr()


def test_cli_rule_subset(capsys):
    bad = os.path.join(FIXTURES, "clock_bad.py")
    assert main([bad, "--no-baseline", "--rules", "AXIS"]) == 0
    assert main([bad, "--no-baseline", "--rules", "CLOCK,AXIS"]) == 1
    capsys.readouterr()


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule.upper() in out


def test_cli_json_report(tmp_path):
    out = tmp_path / "report.json"
    rc = main([os.path.join(FIXTURES, "axis_bad.py"), "--no-baseline",
               "--format", "json", "--output", str(out)])
    assert rc == 1
    doc = json.loads(out.read_text())
    assert doc["summary"]["new"] == len(doc["findings"]) == 5
    assert all(f["rule"] == "AXIS" for f in doc["findings"])
    assert doc["summary"]["by_rule"] == {"AXIS": 5}
    assert set(doc["rules"]) >= {r.upper() for r in RULES}


def test_src_is_clean_modulo_baseline(capsys):
    """The shipped tree is clean with an EMPTY baseline: the session-API
    refactor retired the last grandfathered entries (the chain engine's
    split host syncs are now ONE fused, suppressed sync point)."""
    assert load_baseline(BASELINE) == {}
    assert main([SRC, "--baseline", BASELINE]) == 0
    out = capsys.readouterr().out
    assert "-> clean" in out and "0 baselined" in out


def test_deleting_a_baseline_entry_fails_the_run(tmp_path, capsys):
    """The deletion gate, exercised on a fixture baseline (src/ ships an
    empty one): grandfather a bad file's findings, prune one entry, and the
    resurfaced finding must flip the exit code."""
    src = open(os.path.join(FIXTURES, "clock_bad.py"), encoding="utf-8").read()
    (tmp_path / "m.py").write_text(src)
    findings = analyze_file(str(tmp_path / "m.py"), str(tmp_path), ProjectContext())
    bl = tmp_path / "bl.json"
    assert write_baseline(str(bl), findings, "fixture grandfather") == len(findings)
    assert main([str(tmp_path), "--baseline", str(bl)]) == 0
    baseline = load_baseline(str(bl))
    victim = sorted(baseline)[0]
    pruned = {k: v for k, v in baseline.items() if k != victim}
    bl.write_text(json.dumps({"version": 1, "entries": pruned}))
    assert main([str(tmp_path), "--baseline", str(bl)]) == 1
    capsys.readouterr()


def test_removing_a_suppression_resurfaces_the_finding(tmp_path):
    engine = os.path.join(SRC, "repro", "core", "engine.py")
    text = open(engine, encoding="utf-8").read()
    assert "# repro: disable=HOTSYNC" in text
    project = build_project_context([SRC])
    clean = analyze_file(engine, SRC, project)
    assert not [f for f in clean if f.rule == "HOTSYNC"]

    stripped = re.sub(r"\s*# repro: disable=HOTSYNC[^\n]*", "", text, count=1)
    p = tmp_path / "engine.py"
    p.write_text(stripped)
    findings = analyze_file(str(p), str(tmp_path), project)
    assert [f for f in findings if f.rule == "HOTSYNC"]
