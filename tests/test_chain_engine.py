"""Chain-mode speculation for recurrent-state archs (DESIGN.md §6):
greedy-equality + committed-state consistency on rwkv6 / zamba2."""

import jax
import numpy as np
import pytest

from conftest import greedy_reference
from repro.configs import get_config
from repro.core.chain_engine import ChainConfig, ChainSpecEngine
from repro.models.api import make_model


def _mk(arch, seed=0, peak=4.0):
    cfg = get_config(arch, smoke=True)
    m = make_model(cfg)
    p = m.init(jax.random.PRNGKey(seed))
    p["lm_head"].value = p["lm_head"].value * peak
    return cfg, m, p


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-2.7b"])
@pytest.mark.parametrize("mode", ["serial", "parallel"])
def test_chain_greedy_equality_self_draft(arch, mode):
    cfg, T, tp = _mk(arch)
    prompt = (np.arange(1, 9, dtype=np.int32) % cfg.vocab_size).reshape(1, 8)
    ref = greedy_reference(T, tp, prompt, 24)
    eng = ChainSpecEngine(T, T, ChainConfig(k=4, mode=mode, max_new=24), 256, 256)
    out, stats = eng.generate(tp, tp, prompt)
    assert out[0] == ref[0]
    # self-draft on peaked logits accepts aggressively
    assert stats.compression_ratio > 1.5
    if mode == "parallel":
        assert stats.reused_chains > 0  # full-acceptance chains get reused


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-2.7b"])
def test_chain_greedy_equality_independent_draft(arch):
    """Partial acceptance exercises the recompute-from-pre-state rollback."""
    cfg, T, tp = _mk(arch, seed=0)
    _, _, dp = _mk(arch, seed=7)
    prompt = (np.arange(2, 10, dtype=np.int32) % cfg.vocab_size).reshape(1, 8)
    ref = greedy_reference(T, tp, prompt, 20)
    for mode in ("serial", "parallel"):
        eng = ChainSpecEngine(T, T, ChainConfig(k=4, mode=mode, max_new=20), 256, 256)
        out, _ = eng.generate(tp, dp, prompt)
        assert out[0] == ref[0], mode


def test_chain_state_commit_is_prefix_exact():
    """chain_forward(u, n) must leave the cache exactly as if only u[:n] had
    been decoded step-by-step (the §3.2 consistency analogue for state)."""
    cfg, T, tp = _mk("rwkv6-7b")
    prompt = (np.arange(1, 9, dtype=np.int32) % cfg.vocab_size).reshape(1, 8)
    import jax.numpy as jnp

    _, cache0 = jax.jit(lambda p, t: T.prefill(p, tokens=t, S_max=64))(tp, jnp.asarray(prompt))
    u = jnp.asarray([[5, 9, 13, 21]], jnp.int32)
    n = 2
    _, cache_chain = T.chain_forward(tp, cache0, u, n, 64)

    cache_ref = cache0
    for i in range(n):
        _, cache_ref = T.decode_step(tp, cache_ref, u[:, i : i + 1], 64)

    ref_leaves = jax.tree.leaves(cache_ref)
    got_leaves = jax.tree.leaves(cache_chain)
    for a, b in zip(ref_leaves, got_leaves):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
