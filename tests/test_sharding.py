"""Sharding rules, arbitrary-TP padding equivalence (paper §4), and
multi-device SPMD correctness (subprocess with forced host devices)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, resolve_for_tp
from repro.configs.base import ModelConfig
from repro.models.api import make_model
from repro.models.padding import pad_params
from repro.sharding import DEFAULT_RULES, spec_for


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_spec_for_basic_and_fallback():
    mesh = _FakeMesh({"data": 4, "model": 8})
    assert spec_for(mesh, ("embed", "ff"), (64, 128)) == P("data", "model")
    # non-divisible dims fall back to replication per-dim
    assert spec_for(mesh, ("embed", "ff"), (63, 128)) == P(None, "model")
    assert spec_for(mesh, ("heads", "head_dim"), (6, 128)) == P(None, None)


def test_spec_for_no_axis_reuse():
    mesh = _FakeMesh({"data": 4, "model": 8})
    # both dims map to "model": only the first takes it
    sp = spec_for(mesh, ("ff", "vocab"), (128, 256))
    assert sp == P("model", None)


def test_spec_for_multi_axis_batch():
    mesh = _FakeMesh({"pod": 2, "data": 4, "model": 8})
    sp = spec_for(mesh, ("batch", "seq"), (32, 128))
    assert sp == P(("pod", "data"), None)
    # batch=2 divisible only by pod: trailing axes dropped
    sp2 = spec_for(mesh, ("batch", "seq"), (2, 128))
    assert sp2 == P(("pod",), None) or sp2 == P("pod", None)


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "deepseek-coder-33b", "minicpm3-4b"])
def test_tp_padding_equivalence(arch):
    """Zero-padded heads/ff (resolve_for_tp) produce IDENTICAL logits —
    the paper's arbitrary-TP construction."""
    cfg = get_config(arch, smoke=True)
    tp = 3  # deliberately awkward degree
    cfg_p = resolve_for_tp(cfg, tp)
    assert cfg_p.n_heads % tp == 0 and cfg_p.d_ff % tp == 0

    m, mp = make_model(cfg), make_model(cfg_p)
    params = m.init(jax.random.PRNGKey(0))
    params_p = pad_params(cfg, cfg_p, params, mp.init(jax.random.PRNGKey(1)))

    toks = (jnp.arange(20, dtype=jnp.int32).reshape(2, 10) * 11 + 5) % cfg.vocab_size
    a = m.forward_train(params, tokens=toks)
    b = mp.forward_train(params_p, tokens=toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.api import make_model
from repro.sharding import use_mesh, sharding_for_tree, unbox
from repro.models.transformer import init_model

cfg = get_config("qwen2.5-14b", smoke=True)
m = make_model(cfg)

# single-device reference
params = m.init(jax.random.PRNGKey(0))
toks = (jnp.arange(24, dtype=jnp.int32).reshape(2, 12) * 7 + 1) % cfg.vocab_size
ref = np.asarray(m.forward_train(params, tokens=toks), np.float32)

# SPMD on a (2 data, 4 model) mesh: same math, sharded execution
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = sharding_for_tree(mesh, params)
vals = jax.tree.map(jax.device_put, unbox(params), sh)
import jax.tree_util as jtu
from repro.sharding import Param
boxed_leaves, treedef = jtu.tree_flatten(params, is_leaf=lambda x: isinstance(x, Param))
flat_vals = jtu.tree_leaves(vals)
reboxed = jtu.tree_unflatten(treedef, [Param(v, p.axes) for v, p in zip(flat_vals, boxed_leaves)])

with use_mesh(mesh):
    out = jax.jit(lambda p, t: m.forward_train(p, tokens=t))(reboxed, toks)
got = np.asarray(out, np.float32)
np.testing.assert_allclose(got, ref, atol=2e-4, rtol=2e-4)

# MoE: tp and ep impls agree under SPMD
from repro.flags import override_flags
cfg2 = get_config("deepseek-moe-16b", smoke=True)
m2 = make_model(cfg2)
p2 = m2.init(jax.random.PRNGKey(0))
ref2 = np.asarray(m2.forward_train(p2, tokens=toks % cfg2.vocab_size), np.float32)
with use_mesh(mesh):
    for impl in ("tp", "ep"):
        with override_flags(moe_impl=impl):
            o = jax.jit(lambda p, t: m2.forward_train(p, tokens=t))(p2, toks % cfg2.vocab_size)
        np.testing.assert_allclose(np.asarray(o, np.float32), ref2, atol=3e-4, rtol=3e-4)

# collective matmul variants == plain matmul
from repro.core.collective_matmul import matmul_allreduce, matmul_ag_pipelined
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
want = np.asarray(x @ w)
np.testing.assert_allclose(np.asarray(matmul_allreduce(x, w, mesh)), want, atol=1e-4, rtol=1e-4)
np.testing.assert_allclose(np.asarray(matmul_ag_pipelined(x, w, mesh)), want, atol=1e-4, rtol=1e-4)
print("MULTIDEV_OK")
"""


def test_spmd_multidevice_subprocess():
    """8 forced host devices: sharded forward == single-device forward; MoE
    tp/ep agree; collective matmuls agree.  Subprocess so the main test
    session keeps one device."""
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], capture_output=True,
                       text=True, timeout=900, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "MULTIDEV_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
