"""KV-cache reorganization (paper §3.2): gather/scatter row ops and MovePlan
application, including overlapping src/dst (the compaction case)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import kv as kvm
from repro.models.attention import gather_rows, scatter_rows


def test_scatter_gather_roundtrip():
    cache = jnp.zeros((2, 8, 3))
    rows = jnp.asarray(np.random.default_rng(0).normal(size=(2, 2, 3)), jnp.float32)
    idx = jnp.asarray([[1, 4], [0, 7]], jnp.int32)
    c2 = scatter_rows(cache, rows, idx)
    got = gather_rows(c2, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(rows), atol=1e-6)


def test_scatter_skips_negative_rows():
    cache = jnp.ones((1, 4, 2))
    rows = jnp.full((1, 2, 2), 9.0)
    idx = jnp.asarray([[-1, 2]], jnp.int32)
    c2 = scatter_rows(cache, rows, idx)
    np.testing.assert_allclose(np.asarray(c2[0, 2]), 9.0)
    np.testing.assert_allclose(np.asarray(c2[0, 0]), 1.0)  # untouched


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_apply_moves_overlapping(seed):
    """Moves read all sources BEFORE any write — overlapping plans (compaction
    shifts) must behave like a parallel assignment."""
    rng = np.random.default_rng(seed)
    S, M = 16, 6
    cache = {
        "len": jnp.zeros((), jnp.int32),
        "groups": [({"k": jnp.asarray(rng.normal(size=(2, 1, S, 2, 3)), jnp.float32),
                     "v": jnp.asarray(rng.normal(size=(2, 1, S, 2, 3)), jnp.float32)},)],
    }
    src = rng.choice(S, size=M, replace=False).astype(np.int32)
    dst = rng.choice(S, size=M, replace=False).astype(np.int32)
    mask = rng.random(M) < 0.8

    got = kvm.apply_moves(cache, jnp.asarray(src)[None], jnp.asarray(dst)[None],
                          jnp.asarray(mask)[None])

    want_k = np.array(cache["groups"][0][0]["k"])
    src_vals = want_k[:, :, src].copy()
    for j in range(M):
        if mask[j]:
            want_k[:, :, dst[j]] = src_vals[:, :, j]
    np.testing.assert_allclose(np.asarray(got["groups"][0][0]["k"]), want_k, atol=1e-6)


def test_apply_moves_leaves_non_row_keys():
    cache = {
        "len": jnp.asarray(3, jnp.int32),
        "groups": [({"k": jnp.ones((1, 1, 4, 1, 1)),
                     "ssm": jnp.full((1, 1, 2, 2), 7.0)},)],
    }
    got = kvm.apply_moves(cache, jnp.asarray([[0]]), jnp.asarray([[1]]),
                          jnp.asarray([[True]]))
    np.testing.assert_allclose(np.asarray(got["groups"][0][0]["ssm"]), 7.0)
    assert int(got["len"]) == 3
