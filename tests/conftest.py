import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see ONE device.
# Multi-device tests spawn subprocesses that set the flag themselves.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # hypothesis is an optional test dep (requirements-test.txt); without it
    import hypothesis  # noqa: F401
except ImportError:  # the property tests fall back to a deterministic stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback as _hyp

    sys.modules.setdefault("hypothesis", _hyp)
    sys.modules.setdefault("hypothesis.strategies", _hyp.strategies)

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.api import make_model


@pytest.fixture(scope="session")
def dense_pair():
    """(target, draft) small dense models sharing a vocab, peaked logits."""
    cfgT = ModelConfig(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=128)
    cfgD = ModelConfig(name="d", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=64, vocab_size=128)
    T, D = make_model(cfgT), make_model(cfgD)
    tp = T.init(jax.random.PRNGKey(0))
    dp = D.init(jax.random.PRNGKey(1))
    tp["lm_head"].value = tp["lm_head"].value * 4.0  # peaked greedy chains
    dp["lm_head"].value = dp["lm_head"].value * 4.0
    return T, D, tp, dp


def greedy_reference(model, params, prompt, n, S_max=256):
    """Target-only greedy decoding (the spec-equality oracle)."""
    pref = jax.jit(lambda p, t: model.prefill(p, tokens=t, S_max=S_max))
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, S_max))
    lg, cache = pref(params, jnp.asarray(prompt))
    cur = jnp.argmax(lg[:, -1, :], -1)[:, None].astype(jnp.int32)
    out = [[int(cur[b, 0])] for b in range(prompt.shape[0])]
    for _ in range(n - 1):
        lg, cache = step(params, cache, cur)
        cur = jnp.argmax(lg[:, -1, :], -1)[:, None].astype(jnp.int32)
        for b in range(prompt.shape[0]):
            out[b].append(int(cur[b, 0]))
    return out
