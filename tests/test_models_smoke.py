"""Per-arch smoke tests (assignment requirement): every assigned architecture
instantiates a reduced same-family config, runs forward/train + prefill/decode
on CPU, asserts shapes + finiteness + cache-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.api import make_model


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_train(arch):
    cfg = get_config(arch, smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 7 + 3) % cfg.vocab_size
    kw = {}
    if cfg.n_enc_tokens:
        kw["enc"] = jnp.full((B, cfg.n_enc_tokens, cfg.d_model), 0.01, jnp.float32)
    if cfg.embed_inputs:
        logits = m.forward_train(params, tokens=toks, **kw)
    else:
        emb = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.02
        logits = m.forward_train(params, embeds=emb, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_then_decode_matches_full_forward(arch):
    """Cache-path consistency: prefill(S) then decode(1) must produce the same
    next-token logits as a full forward over S+1 tokens."""
    cfg = get_config(arch, smoke=True)
    if not cfg.embed_inputs:
        pytest.skip("stub-frontend arch: decode path tested via engine tests")
    m = make_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 10
    toks = (jnp.arange(B * (S + 1), dtype=jnp.int32).reshape(B, S + 1) * 5 + 2) % cfg.vocab_size
    kw = {}
    if cfg.n_enc_tokens:
        kw["enc"] = jnp.full((B, cfg.n_enc_tokens, cfg.d_model), 0.01, jnp.float32)

    full = m.forward_train(params, tokens=toks, **kw)  # [B, S+1, V]
    _, cache = m.prefill(params, tokens=toks[:, :S], S_max=32, **kw)
    dec, _ = m.decode_step(params, cache, toks[:, S:], 32)

    np.testing.assert_allclose(
        np.asarray(dec[:, 0], np.float32), np.asarray(full[:, S], np.float32),
        atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step_no_nans(arch):
    """One fwd+bwd+AdamW step per arch: finite loss, finite updated params."""
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init

    cfg = get_config(arch, smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = make_train_step(cfg, m)
    B, S = 2, 8
    toks = (jnp.arange(B * (S + 1), dtype=jnp.int32).reshape(B, S + 1) * 3 + 1) % cfg.vocab_size
    batch = {"tokens": toks}
    if not cfg.embed_inputs:
        batch = {
            "embeds": jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.02,
            "labels": toks[:, 1:],
        }
    if cfg.n_enc_tokens:
        batch["enc"] = jnp.full((B, cfg.n_enc_tokens, cfg.d_model), 0.01, jnp.float32)
    new_params, new_opt, loss = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(loss)), arch
    assert bool(jnp.isfinite(new_params["lm_head"].value).all())
    assert int(new_opt.step) == 1


def test_wkv_chunked_equals_stepwise():
    """§Perf B2: the chunked segment-sum WKV form must match the per-step
    recurrence (same contract as the mamba2 chunk/step pair)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models import rwkv6 as rk

    rng = np.random.default_rng(0)
    B, S, H, hd = 2, 48, 3, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32) for _ in range(3))
    logw = -jnp.asarray(rng.random((B, S, H, hd)) * 2 + 0.01, jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, hd)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, hd, hd)), jnp.float32)
    y1, sf1 = rk._wkv_scan(r, k, v, jnp.exp(logw), u, s0)
    y2, sf2 = rk._wkv_chunked(r, k, v, logw, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2), atol=5e-4, rtol=5e-4)
