"""Draft-tree algebra unit + property tests (paper §3.1–3.2).

Hypothesis drives random expansion/verification trajectories and asserts the
structural invariants that KV-cache consistency rests on:
  * node 0 is the root; every valid node's ancestors are valid and expanded;
  * weights are non-increasing along root→leaf paths;
  * select_batch returns an ancestor-closed, weight-sorted subgraph;
  * after reroot: surviving nodes are exactly the old root-child subtree,
    compacted; accepted-path KV moves into the prefix; no surviving KV row
    is lost or duplicated.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import tree as T

N_CAP = 32
C = 2
S_MAX = 128


def build_tree(seed: int, n_expansions: int, w: int = 3):
    rng = np.random.default_rng(seed)
    tr = T.init_tree(N_CAP)
    logits = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    tr = T.seed_root(tr, token=5, plen=10, root_logits=jnp.pad(logits, (0, 0)), c=C)
    for _ in range(n_expansions):
        ids, valid = T.select_leaves(tr, w)
        toks, rows, pos, mask, _ = T.leaf_inputs(tr, ids, valid, S_MAX)
        ct = jnp.asarray(rng.integers(0, 64, size=(w, C)), jnp.int32)
        cl = jnp.asarray(-rng.random((w, C)), jnp.float32)
        cl = -jnp.sort(-cl, axis=1)  # children sorted by prob, like top_k
        tr = T.insert_children(tr, ids, valid, rows, ct, cl)
    return tr


def _np(t):
    return jax.tree.map(np.asarray, t)


@given(st.integers(0, 10_000), st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_tree_structural_invariants(seed, n_exp):
    tr = _np(build_tree(seed, n_exp))
    n = int(tr.n_nodes)
    assert 1 <= n <= N_CAP
    assert tr.parent[0] == -1 and tr.valid[0] and tr.expanded[0]
    for i in range(1, n):
        if not tr.valid[i]:
            continue
        p = int(tr.parent[i])
        assert 0 <= p < i, "parents precede children"
        assert tr.valid[p] and tr.expanded[p]
        assert tr.weight[i] <= tr.weight[p] + 1e-6
        assert tr.depth[i] == tr.depth[p] + 1
        if tr.kv_row[i] >= 0:
            assert tr.expanded[i]


@given(st.integers(0, 10_000), st.integers(1, 5), st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_select_batch_ancestor_closed(seed, n_exp, bs):
    tr = build_tree(seed, n_exp)
    plan = T.select_batch(tr, bs, S_MAX)
    plan = _np(plan)
    trn = _np(tr)
    ids = plan.node_ids
    assert plan.valid[0] and ids[0] == 0, "slot 0 is the root"
    sel = set(int(i) for i, v in zip(ids, plan.valid) if v)
    for i, v in zip(ids, plan.valid):
        if not v or int(i) == 0:
            continue
        assert int(trn.parent[int(i)]) in sel, "ancestor-closed subgraph"
    # weights are the bs best among valid nodes
    w_sel = sorted((float(trn.weight[i]) for i in sel), reverse=True)
    w_all = sorted((float(w) for w, v in zip(trn.weight, trn.valid) if v), reverse=True)
    assert np.allclose(w_sel, w_all[: len(w_sel)], atol=1e-6)


@given(st.integers(0, 10_000), st.integers(1, 4), st.data())
@settings(max_examples=25, deadline=None)
def test_reroot_consistency(seed, n_exp, data):
    tr = build_tree(seed, n_exp)
    bs = 6
    plan = T.select_batch(tr, bs, S_MAX)
    trn, plann = _np(tr), _np(plan)

    # drive verify_walk with arbitrary "target argmax" choices
    argmax = data.draw(st.lists(st.integers(0, 63), min_size=bs, max_size=bs))
    acc_pos, n_acc, bonus, emitted, n_emitted = T.verify_walk(
        plan.tokens, plan.parent_pos, plan.valid, jnp.asarray(argmax, jnp.int32)
    )
    tr2, move, fill = T.reroot(tr, plan.node_ids, acc_pos, n_acc, bonus)
    tr2n, moven = _np(tr2), _np(move)

    # --- prefix bookkeeping -------------------------------------------------
    assert int(tr2n.plen) == int(trn.plen) + int(n_acc) + 1
    assert tr2n.parent[0] == -1 and tr2n.valid[0]
    assert int(tr2n.tokens[0]) == int(bonus)
    assert tr2n.weight[0] == 0.0 and tr2n.depth[0] == 0

    # --- surviving subtree --------------------------------------------------
    n2 = int(tr2n.n_nodes)
    for i in range(1, n2):
        p = int(tr2n.parent[i])
        assert 0 <= p < i
        assert tr2n.weight[i] <= tr2n.weight[p] + 1e-6

    # --- KV moves: no duplicate destinations, accepted rows -> prefix -------
    dsts = moven.dst[moven.mask]
    assert len(set(dsts.tolist())) == len(dsts), "KV destinations unique"
    srcs = moven.src[moven.mask]
    assert (srcs >= 0).all()
    n_prefix_moves = int((dsts < tr2n.plen).sum())
    assert n_prefix_moves <= int(n_acc) + 1

    # --- accepted-path prefix rows are covered exactly once: every row in
    # [plen_old, plen_new-1) comes from either a KV move or a fill forward
    filln = _np(fill)
    covered = sorted(
        [int(d) for d in dsts if int(trn.plen) <= d < int(tr2n.plen) - 1]
        + [int(r) for r, mk in zip(filln.rows, filln.mask) if mk]
    )
    expect = list(range(int(trn.plen), int(tr2n.plen) - 1))
    assert covered == expect, (covered, expect)


def test_verify_walk_greedy_path():
    """Deterministic example: walk accepts exactly the argmax chain."""
    tokens = jnp.asarray([5, 7, 9, 11], jnp.int32)  # slot 0 = root
    parent_pos = jnp.asarray([-1, 0, 1, 0], jnp.int32)
    valid = jnp.ones(4, bool)
    # argmax: root->7 (slot1), slot1->9 (slot2), slot2->42 (not in tree)
    argmax = jnp.asarray([7, 9, 42, 0], jnp.int32)
    acc, n_acc, bonus, emitted, n_emitted = T.verify_walk(tokens, parent_pos, valid, argmax)
    assert int(n_acc) == 2 and int(bonus) == 42
    assert np.asarray(emitted)[:3].tolist() == [7, 9, 42]
    assert int(n_emitted) == 3


def test_rows_mask_non_square():
    """The paper's non-square mask: leaves attend prefix + ancestors + self."""
    tr = build_tree(0, 2)
    ids, valid = T.select_leaves(tr, 3)
    toks, rows, pos, mask, _ = T.leaf_inputs(tr, ids, valid, S_MAX)
    trn, maskn, idsn, rowsn = _np(tr), np.asarray(mask), np.asarray(ids), np.asarray(rows)
    for q in range(3):
        if not np.asarray(valid)[q]:
            assert not maskn[q].any()
            continue
        assert maskn[q, : int(trn.plen)].all(), "prefix rows visible"
        assert maskn[q, int(rowsn[q])], "self row visible"
        # ancestors' kv rows visible
        node = int(idsn[q])
        p = int(trn.parent[node])
        while p >= 0:
            r = int(trn.kv_row[p])
            if r >= 0:
                assert maskn[q, r]
            p = int(trn.parent[p])
