"""Continuous-batching serving runtime (repro.serving).

The correctness contract: a request's emitted stream is byte-identical to a
solo ``generate()`` run no matter when it was admitted, which slot it landed
in, or what its neighbors were doing — plus slot-recycling hygiene (a retired
slot's KV/tree state cannot leak into its successor) and queue/admission
invariants under a burst trace.
"""

import jax
import numpy as np
import pytest

from repro.core import kv as kvm
from repro.core.engine import SpecConfig, SpecEngine
from repro.serving import ContinuousBatchingRuntime, Request, RequestQueue, VirtualClock


@pytest.fixture(scope="module")
def serving_engine(dense_pair):
    T, D, tp, dp = dense_pair
    cfg = SpecConfig(bs=8, w=4, c=2, d=2, n_cap=64, mode="parallel", max_new=24)
    return SpecEngine(T, D, cfg, S_max_t=256, S_max_d=256), tp, dp


def _prompt(k, P=8):
    return ((np.arange(1, P + 1) * k + 3) % 128).astype(np.int32)


# ---------------------------------------------------------------------------
# greedy equivalence under continuous batching
# ---------------------------------------------------------------------------


def test_continuous_matches_solo_generate(serving_engine):
    """Five staggered requests through two slots: every output equals its
    solo generate() run, and lifetimes overlap (mid-flight admission)."""
    eng, tp, dp = serving_engine
    reqs = [Request(rid=i, prompt=_prompt(i + 1, P=8 + 4 * (i % 2)),
                    arrival_s=0.7 * i, max_new=16) for i in range(5)]
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=2, clock=VirtualClock())
    assert rt.submit_trace(reqs) == 5
    results = rt.run()

    assert sorted(results) == [0, 1, 2, 3, 4]
    for r in reqs:
        solo, _ = eng.generate(tp, dp, r.prompt.reshape(1, -1), max_new=r.max_new)
        assert results[r.rid] == solo[0], f"request {r.rid} diverged from solo generate()"

    # continuous batching actually happened: some request was admitted while
    # another was still in flight (overlapping [admit, finish) round ranges)
    recs = sorted(rt.stats.records.values(), key=lambda r: r.admit_round)
    overlaps = [
        (a.rid, b.rid)
        for a in recs for b in recs
        if a.rid != b.rid and a.admit_round < b.finish_round and b.admit_round < a.finish_round
    ]
    assert overlaps, "no overlapping request lifetimes — not continuous batching"
    assert max(rt.stats.occupancy_samples) == 2  # both slots were in use at once


def test_streaming_delivery(serving_engine):
    """The stream callback sees every token, in order, before run() returns."""
    eng, tp, dp = serving_engine
    got = {}
    rt = ContinuousBatchingRuntime(
        eng, tp, dp, n_slots=2, clock=VirtualClock(),
        stream=lambda rid, toks, done: got.setdefault(rid, []).extend(toks),
    )
    reqs = [Request(rid=i, prompt=_prompt(7 + i), arrival_s=0.0, max_new=12) for i in range(3)]
    rt.submit_trace(reqs)
    results = rt.run()
    assert got == results


def test_live_submit_after_trace_run(serving_engine):
    """The runtime stays usable after a trace: a later submit with the
    default arrival_s=0.0 arrives 'now' instead of violating queue order."""
    eng, tp, dp = serving_engine
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=VirtualClock())
    rt.submit(Request(rid=0, prompt=_prompt(4), arrival_s=2.0, max_new=8))
    rt.run()
    assert rt.submit(Request(rid=1, prompt=_prompt(6), max_new=8))  # arrival in the past
    results = rt.run()
    assert sorted(results) == [0, 1]
    solo, _ = eng.generate(tp, dp, _prompt(6).reshape(1, -1), max_new=8)
    assert results[1] == solo[0]
    assert rt.stats.summary()["n_finished"] == 2


def test_live_submit_mid_run_does_not_break_trace_feed(serving_engine):
    """A stream-callback submit() racing a not-yet-fed trace entry: the live
    request is clamped to 'now', and the trace entry (older true arrival)
    still feeds cleanly on the next loop turn — no ordering crash, all three
    requests served."""
    eng, tp, dp = serving_engine
    sent = []

    def stream(rid, toks, done):
        if not sent and rt.clock.now() >= 3.0:
            sent.append(rid)
            assert rt.submit(Request(rid=2, prompt=_prompt(8), max_new=4))

    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=VirtualClock(),
                                   stream=stream)
    rt.submit(Request(rid=0, prompt=_prompt(4), arrival_s=0.0, max_new=12))
    rt.submit(Request(rid=1, prompt=_prompt(5), arrival_s=2.5, max_new=4))
    results = rt.run()
    assert sorted(results) == [0, 1, 2]
    solo, _ = eng.generate(tp, dp, _prompt(8).reshape(1, -1), max_new=4)
    assert results[2] == solo[0]


def test_eos_inherited_from_engine(dense_pair, serving_engine):
    """A Request without an explicit eos_id follows the ENGINE's eos_id, so
    the byte-identical contract holds for engines that stop early."""
    T, D, tp, dp = dense_pair
    base, _, _ = serving_engine
    prompt = _prompt(9)
    probe, _ = base.generate(tp, dp, prompt.reshape(1, -1), max_new=20)
    eos = probe[0][10]  # a token the greedy stream provably reaches
    eng = SpecEngine(T, D, SpecConfig(bs=8, w=4, c=2, d=2, n_cap=64, max_new=20,
                                      eos_id=eos), S_max_t=256, S_max_d=256)
    solo, _ = eng.generate(tp, dp, prompt.reshape(1, -1), max_new=20)
    assert eos in solo[0]
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=VirtualClock())
    rt.submit(Request(rid=0, prompt=prompt, max_new=20))
    assert rt.run()[0] == solo[0]


def test_immediate_eos_request_record_shape(serving_engine):
    """A request whose very first verified token is its EOS: it finishes in
    its first round with exactly that one token, and the telemetry record is
    fully formed (TTFT present, finish stamped, one-round lifetime)."""
    eng, tp, dp = serving_engine
    prompt = _prompt(13)
    probe, _ = eng.generate(tp, dp, prompt.reshape(1, -1), max_new=4)
    eos = probe[0][0]  # the first greedy token
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=2, clock=VirtualClock())
    rt.submit(Request(rid=0, prompt=prompt, max_new=16, eos_id=eos))
    results = rt.run()
    assert results[0] == [eos]
    rec = rt.stats.records[0]
    assert rec.n_tokens == 1 and rec.n_rounds == 1
    assert rec.ttft_s is not None and rec.finish_s is not None
    assert rec.finish_round == rec.admit_round + 1
    assert rec.first_token_s == rec.finish_s
    assert rec.tok_per_s is not None  # finish strictly after admit (one round)
    s = rt.stats.summary()
    assert s["n_finished"] == 1 and s["total_tokens"] == 1
    assert s["ttft_p50_s"] == pytest.approx(rec.ttft_s)


def test_plen_budget_single_definition(serving_engine):
    """The KV-budget bound has ONE definition: the serving runtime inherits
    engine.plen_budget verbatim (drift here silently breaks the
    byte-identical contract for requests near the budget)."""
    eng, tp, dp = serving_engine
    assert eng.plen_budget == min(eng.S_max_t, eng.S_max_d) - 2 * eng.cfg.bs
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=VirtualClock())
    assert rt._plen_limit == eng.plen_budget
    assert rt.stepper.plen_limit == eng.plen_budget


# ---------------------------------------------------------------------------
# slot recycling
# ---------------------------------------------------------------------------


def test_slot_recycling_no_leakage(serving_engine):
    """Two requests serially through ONE slot: the successor's output is
    unaffected by its predecessor, and release physically zeroes the rows."""
    eng, tp, dp = serving_engine
    a, b = _prompt(5, P=12), _prompt(11, P=8)
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=VirtualClock())
    rt.submit(Request(rid=0, prompt=a, arrival_s=0.0, max_new=16))
    rt.submit(Request(rid=1, prompt=b, arrival_s=0.0, max_new=16))
    results = rt.run()

    solo_b, _ = eng.generate(tp, dp, b.reshape(1, -1), max_new=16)
    assert results[1] == solo_b[0], "retired slot state leaked into its successor"

    # after the final release, every cache row of the slot is physically zero
    for cache in (rt.state.tcache, rt.state.dcache):
        leaves = jax.tree.leaves(cache["groups"])
        assert leaves and all(not np.asarray(leaf).any() for leaf in leaves)


def test_release_slot_targets_one_row(serving_engine):
    """zero_slot/reset_slot touch exactly the released row."""
    eng, tp, dp = serving_engine
    state = eng.init_state(2)
    state = eng.admit_slot(tp, dp, state, 0, _prompt(3))
    state = eng.admit_slot(tp, dp, state, 1, _prompt(4))
    before = [np.asarray(x) for x in jax.tree.leaves(state.tcache["groups"])]
    state = eng.release_slot(state, 0)
    after = [np.asarray(x) for x in jax.tree.leaves(state.tcache["groups"])]
    for b4, af in zip(before, after):
        assert not af[:, 0].any(), "released row not cleared"
        np.testing.assert_array_equal(af[:, 1], b4[:, 1])  # neighbor untouched
    assert not np.asarray(state.tr.valid[0]).any()
    assert np.asarray(state.tr.valid[1]).any()


def test_install_zero_slot_roundtrip():
    """kv.install_slot / kv.zero_slot unit behaviour on a toy cache."""
    import jax.numpy as jnp

    def mk(v):
        return {"len": jnp.zeros((), jnp.int32),
                "groups": [{"k": v, "v": 2 * v}]}

    big = mk(jnp.zeros((2, 3, 4, 5), jnp.float32))
    one = mk(jnp.asarray(np.random.default_rng(0).normal(size=(2, 1, 4, 5)), jnp.float32))
    out = kvm.install_slot(big, one, 1)
    np.testing.assert_allclose(np.asarray(out["groups"][0]["k"][:, 1]), one["groups"][0]["k"][:, 0])
    assert not np.asarray(out["groups"][0]["k"][:, 0]).any()
    out2 = kvm.zero_slot(out, 1)
    assert not np.asarray(out2["groups"][0]["k"]).any()
    np.testing.assert_allclose(np.asarray(out2["groups"][0]["v"][:, 2]),
                               np.asarray(out["groups"][0]["v"][:, 2]))


# ---------------------------------------------------------------------------
# queue / admission invariants
# ---------------------------------------------------------------------------


def test_queue_depth_o1_bookkeeping():
    """depth() is an O(1) arrived-count for monotonic ``now`` (the runtimes'
    usage), stays exact as requests arrive/pop, and an out-of-order probe
    behind the watermark still answers exactly."""
    q = RequestQueue(cap=8)
    for i in range(3):
        q.submit(Request(rid=i, prompt=np.ones(4), arrival_s=float(i)))
    assert q.depth(now=1.5) == 2
    assert q.pop_ready(now=1.5).rid == 0
    assert q.depth(now=1.5) == 1
    assert q.pop_ready(now=1.5).rid == 1
    assert q.pop_ready(now=1.5) is None  # rid 2 hasn't arrived
    assert q.next_arrival() == 2.0
    assert q.depth(now=2.5) == 1
    # a submission at/behind the watermark is immediately arrived
    q.submit(Request(rid=3, prompt=np.ones(4), arrival_s=2.5))
    assert q.depth(now=2.5) == 2
    assert q.pending == 2 and len(q) == 2
    # non-monotonic probe: exact answer, not the cached watermark count
    assert q.depth(now=0.0) == 0
    assert q.depth(now=2.0) == 1


def test_admission_gate_and_stamp_share_one_timestamp(serving_engine):
    """_admit_ready reads the clock once per admission: the pop_ready gate
    value IS the on_admit stamp (a clock that advances on every read would
    otherwise skew queue_s/TTFT)."""
    eng, tp, dp = serving_engine

    class StutterClock(VirtualClock):
        def now(self):  # every read advances: a double read is detectable
            t, self._t = self._t, self._t + 1e-3
            return t

    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=StutterClock())
    gates = []
    orig = rt.queue.pop_ready
    rt.queue.pop_ready = lambda now: (gates.append(now), orig(now))[1]
    rt.submit(Request(rid=0, prompt=_prompt(2), max_new=4))
    rt.run()
    assert rt.stats.records[0].admitted_s in gates


def test_queue_admission_control():
    q = RequestQueue(cap=3)
    ok = [q.submit(Request(rid=i, prompt=np.ones(4), arrival_s=float(i))) for i in range(5)]
    assert ok == [True, True, True, False, False]
    assert q.submitted == 5 and q.rejected == 2 and len(q) == 3
    # arrival gating: nothing poppable before its arrival time
    assert q.pop_ready(now=-1.0) is None
    assert q.depth(now=1.5) == 2
    r0 = q.pop_ready(now=0.0)
    assert r0.rid == 0  # FIFO
    assert q.next_arrival() == 1.0
    # freed capacity admits again
    assert q.submit(Request(rid=9, prompt=np.ones(4), arrival_s=9.0))
    assert q.pop_ready(now=9.0).rid == 1
    # an already-arrived submission is always orderable: it queues behind
    # everything already here (live submits cannot poison the queue)
    assert q.submit(Request(rid=10, prompt=np.ones(4), arrival_s=0.5))
    assert q.pop_ready(now=9.0).rid == 2  # FIFO by insertion
    # but FUTURE submissions must stay arrival-ordered (trace sanity)
    assert q.submit(Request(rid=11, prompt=np.ones(4), arrival_s=20.0))
    with pytest.raises(ValueError):
        q.submit(Request(rid=12, prompt=np.ones(4), arrival_s=15.0))


def test_burst_trace_invariants(serving_engine):
    """A burst larger than the queue cap: the overflow is shed at the door,
    every admitted request finishes, occupancy never exceeds the slots."""
    eng, tp, dp = serving_engine
    rt = ContinuousBatchingRuntime(
        eng, tp, dp, n_slots=2, clock=VirtualClock(),
        queue=RequestQueue(cap=4),
    )
    reqs = [Request(rid=i, prompt=_prompt(2 * i + 1), arrival_s=0.0, max_new=8)
            for i in range(6)]
    assert rt.submit_trace(reqs) == 4
    assert rt.queue.rejected == 2
    results = rt.run()
    assert sorted(results) == [0, 1, 2, 3]
    assert all(len(v) == 8 for v in results.values())
    assert all(r.finish_s is not None for r in rt.stats.records.values())
    assert max(rt.stats.occupancy_samples) <= 2
    # an ARRIVED prompt that cannot fit the cache budget is rejected at submit()
    assert not rt.submit(Request(rid=99, prompt=np.ones(250, np.int32), arrival_s=0.0))
    assert rt.queue.rejected == 3


def test_overlong_prompt_rejected_at_arrival_not_submit(serving_engine):
    """A too-long prompt with a FUTURE arrival is accepted at submit time and
    shed when it arrives — same live-traffic semantics as the queue cap — so
    submitted/rejected counters reflect offered load, not trace length."""
    eng, tp, dp = serving_engine
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=1, clock=VirtualClock())
    assert rt.submit(Request(rid=0, prompt=_prompt(3), arrival_s=0.0, max_new=8))
    # deferred: nothing counted against the queue yet
    assert rt.submit(Request(rid=1, prompt=np.ones(250, np.int32), arrival_s=5.0))
    assert rt.queue.submitted == 1 and rt.queue.rejected == 0
    results = rt.run()
    assert sorted(results) == [0]
    # the reject landed when the clock reached arrival_s=5.0
    assert rt.queue.submitted == 2 and rt.queue.rejected == 1
    assert 1 not in rt.stats.records


def test_cap_sheds_on_arrived_backlog_not_trace_length(serving_engine):
    """A long trace with spread-out arrivals never builds a backlog, so a cap
    smaller than the trace sheds nothing (live-traffic admission semantics)."""
    eng, tp, dp = serving_engine
    rt = ContinuousBatchingRuntime(
        eng, tp, dp, n_slots=1, clock=VirtualClock(),
        queue=RequestQueue(cap=2),
    )
    reqs = [Request(rid=i, prompt=_prompt(3 * i + 2), arrival_s=40.0 * i, max_new=8)
            for i in range(5)]  # each finishes in ~8 rounds << 40 between arrivals
    assert rt.submit_trace(reqs) == 5
    results = rt.run()
    assert sorted(results) == [0, 1, 2, 3, 4]
    assert rt.queue.rejected == 0, "cap must shed on arrived backlog, not trace length"


# ---------------------------------------------------------------------------
# per-row stats accounting (engine satellite)
# ---------------------------------------------------------------------------


def test_specstats_per_row_exact(dense_pair):
    """No per-round floor division: emitted_rows[b] == accepted_rows[b] +
    rounds (each row emits its acceptances + 1 bonus every round)."""
    T, D, tp, dp = dense_pair
    eng = SpecEngine(T, D, SpecConfig(bs=8, w=4, c=2, d=2, max_new=12),
                     S_max_t=256, S_max_d=256)
    prompt = (np.arange(16, dtype=np.int32).reshape(2, 8) * 3 + 1) % 128
    out, stats = eng.generate(tp, dp, prompt, max_new=12)
    assert stats.emitted_rows.shape == (2,)
    np.testing.assert_array_equal(stats.emitted_rows, stats.accepted_rows + stats.rounds)
    assert all(er >= len(o) for er, o in zip(stats.emitted_rows, out))
    assert stats.emitted == pytest.approx(stats.emitted_rows.mean())
    assert stats.total_emitted == int(stats.emitted_rows.sum())
