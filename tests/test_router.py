"""ShardedServingRuntime (repro.serving.router).

The contracts under test: the routing policy (least-loaded replica wins a
popped request, FIFO tie-break so equal load spreads instead of piling onto
replica 0), the shared global queue (admission control spans the fleet),
per-replica/fleet telemetry merging, and — above all — that sharding is
schedule-only: every request's output is byte-identical to a solo
``generate()`` run regardless of which replica served it.
"""

import numpy as np
import pytest

from repro.core.engine import SpecConfig, SpecEngine
from repro.serving import (
    ContinuousBatchingRuntime,
    Request,
    RequestQueue,
    ShardedServingRuntime,
    VirtualClock,
    fleet_report,
    merge_summary,
)


@pytest.fixture(scope="module")
def sharded_engine(dense_pair):
    T, D, tp, dp = dense_pair
    cfg = SpecConfig(bs=8, w=4, c=2, d=2, n_cap=64, mode="parallel", max_new=24)
    return SpecEngine(T, D, cfg, S_max_t=256, S_max_d=256), tp, dp


def _prompt(k, P=8):
    return ((np.arange(1, P + 1) * k + 3) % 128).astype(np.int32)


def _fleet(eng, tp, dp, n_rep=2, n_slots=2, **kw):
    # the same engine object N times: states are per-replica, jit cache shared
    return ShardedServingRuntime([eng] * n_rep, tp, dp, n_slots=n_slots,
                                 clock=VirtualClock(), **kw)


# ---------------------------------------------------------------------------
# routing policy (pure, no engine)
# ---------------------------------------------------------------------------


class _Stub:
    def __init__(self, occupied, n_slots, slack=float("inf")):
        self.occupied, self.n_slots = occupied, n_slots
        self.has_free_slot = occupied < n_slots
        self.load = occupied / n_slots
        self._slack = slack

    def deadline_slack(self, now):
        return self._slack


def _router(stubs, last_dispatch=None):
    rt = object.__new__(ShardedServingRuntime)
    rt.steppers = stubs
    rt._last_dispatch = last_dispatch if last_dispatch is not None else [-1] * len(stubs)
    return rt


def test_route_picks_least_loaded():
    rt = _router([_Stub(1, 2), _Stub(0, 2)])
    assert rt._route(0.0) == 1  # 0.5 vs 0.0 load
    rt = _router([_Stub(0, 2), _Stub(1, 2)])
    assert rt._route(0.0) == 0


def test_route_load_is_a_fraction_not_a_count():
    # 3/8 occupied beats 1/2 occupied: the occupancy FRACTION routes (a raw
    # count would send this to replica 0), so heterogeneous slot counts
    # still balance
    rt = _router([_Stub(1, 2), _Stub(3, 8)])
    assert rt._route(0.0) == 1
    rt = _router([_Stub(2, 4), _Stub(3, 4)])
    assert rt._route(0.0) == 0


def test_route_fifo_tiebreak_spreads_equal_load():
    # equal load: the replica whose last admission is OLDEST wins
    rt = _router([_Stub(1, 2), _Stub(1, 2)], last_dispatch=[2, 1])
    assert rt._route(0.0) == 1
    rt = _router([_Stub(1, 2), _Stub(1, 2)], last_dispatch=[1, 2])
    assert rt._route(0.0) == 0


def test_route_skips_full_replicas_and_full_fleet():
    rt = _router([_Stub(2, 2), _Stub(1, 2)])
    assert rt._route(0.0) == 1  # replica 0 is full
    rt = _router([_Stub(2, 2), _Stub(2, 2)])
    assert rt._route(0.0) is None  # fleet full: leave the queue alone


def test_route_slack_breaks_load_ties_before_fifo():
    # equal load, replica 0 has a deadline 2s out, replica 1 has 10s of
    # slack: the new admission steers to the replica with MORE slack even
    # though FIFO (last_dispatch) would have picked replica 0
    rt = _router([_Stub(1, 2, slack=2.0), _Stub(1, 2, slack=10.0)],
                 last_dispatch=[1, 2])
    assert rt._route(0.0) == 1
    # unequal load still dominates: the tighter replica wins when emptier
    rt = _router([_Stub(0, 2, slack=2.0), _Stub(1, 2, slack=10.0)],
                 last_dispatch=[1, 2])
    assert rt._route(0.0) == 0
    # deadline-free fleets (all +inf slack) keep the exact FIFO tie-break
    rt = _router([_Stub(1, 2), _Stub(1, 2)], last_dispatch=[2, 1])
    assert rt._route(0.0) == 1


# ---------------------------------------------------------------------------
# end-to-end sharded serving
# ---------------------------------------------------------------------------


def test_requests_land_on_least_loaded_replica(sharded_engine):
    """Three simultaneous arrivals over 2x2 slots: replica 0 takes the
    first (tie-break), replica 1 the second (now least loaded), replica 0
    the third (equal load, oldest last-admission)."""
    eng, tp, dp = sharded_engine
    rt = _fleet(eng, tp, dp, n_rep=2, n_slots=2)
    rt.submit_trace(Request(rid=i, prompt=_prompt(i + 1), arrival_s=0.0, max_new=8)
                    for i in range(3))
    rt.run()
    assert [rt.replica_of(i) for i in range(3)] == [0, 1, 0]
    # the tags in the per-replica stats agree with the router's view
    for i in range(3):
        rep = rt.replica_of(i)
        assert rt.stats[rep].records[i].replica == rep


def test_sharded_byte_identical_to_solo_generate(sharded_engine):
    """Six staggered requests across 2 replicas: both replicas serve, and
    every output equals its solo generate() run — sharding changes the
    schedule, never the tokens."""
    eng, tp, dp = sharded_engine
    rt = _fleet(eng, tp, dp, n_rep=2, n_slots=2)
    reqs = [Request(rid=i, prompt=_prompt(i + 2, P=8 + 4 * (i % 2)),
                    arrival_s=0.4 * i, max_new=12) for i in range(6)]
    assert rt.submit_trace(reqs) == 6
    results = rt.run()
    assert sorted(results) == list(range(6))
    assert {rt.replica_of(i) for i in range(6)} == {0, 1}
    for r in reqs:
        solo, _ = eng.generate(tp, dp, r.prompt.reshape(1, -1), max_new=r.max_new)
        assert results[r.rid] == solo[0], (
            f"request {r.rid} on replica {rt.replica_of(r.rid)} diverged")
    for st in rt.stats:
        assert max(st.occupancy_samples, default=0) <= 2


def test_single_replica_degenerates_to_continuous_runtime(sharded_engine):
    """A 1-replica fleet produces exactly the single-engine runtime's
    outputs for the same trace (one shared stepper implementation)."""
    eng, tp, dp = sharded_engine
    reqs = [dict(rid=i, prompt=_prompt(3 * i + 1), arrival_s=0.5 * i, max_new=8)
            for i in range(3)]
    solo_rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=2, clock=VirtualClock())
    solo_rt.submit_trace(Request(**r) for r in reqs)
    fleet = _fleet(eng, tp, dp, n_rep=1, n_slots=2)
    fleet.submit_trace(Request(**r) for r in reqs)
    assert solo_rt.run() == fleet.run()


def test_global_queue_cap_spans_fleet(sharded_engine):
    """One global cap sheds the burst overflow no matter how many replicas
    exist; every admitted request finishes somewhere."""
    eng, tp, dp = sharded_engine
    rt = _fleet(eng, tp, dp, n_rep=2, n_slots=1, queue=RequestQueue(cap=3))
    assert rt.submit_trace(
        Request(rid=i, prompt=_prompt(2 * i + 1), arrival_s=0.0, max_new=8)
        for i in range(5)) == 3
    assert rt.queue.rejected == 2
    results = rt.run()
    assert sorted(results) == [0, 1, 2]
    assert all(len(v) == 8 for v in results.values())


def test_fleet_stats_merge(sharded_engine):
    """merge_summary folds per-replica stats into one global view; the
    fleet report carries per-replica occupancy lines."""
    eng, tp, dp = sharded_engine
    rt = _fleet(eng, tp, dp, n_rep=2, n_slots=2)
    rt.submit_trace(Request(rid=i, prompt=_prompt(i + 4), arrival_s=0.3 * i, max_new=8)
                    for i in range(4))
    rt.run()
    s = rt.summary()
    assert s["n_replicas"] == 2
    assert s["n_finished"] == 4 == sum(s["per_replica_finished"])
    assert s["total_tokens"] == 4 * 8
    assert s["throughput_tok_s"] > 0
    assert len(s["per_replica_occupancy"]) == 2
    assert s["ttft_p50_s"] == s["ttft_p50_s"]  # not NaN
    report = rt.report()
    assert "replica 0:" in report and "replica 1:" in report and "fleet:" in report
    assert fleet_report(rt.stats) == report
    # summary() additionally folds the per-replica accept-depth histograms
    # (union-merged edges); modulo those keys it IS merge_summary.  SLO
    # fields are nan here (no request carried a deadline), so compare
    # nan-aware: nan == nan for this purpose
    base = merge_summary(rt.stats)
    for k, v in base.items():
        got = s[k]
        if isinstance(v, float) and v != v:
            assert got != got, k
        else:
            assert got == v, k
    assert s["accept_depth_hist"]["count"] > 0
    assert s["accept_depth_mean"] == pytest.approx(
        s["accept_depth_hist"]["sum"] / s["accept_depth_hist"]["count"])


def test_long_prefill_on_one_replica_does_not_block_admission_order(sharded_engine):
    """While replica 0 is mid-flight on a long request, a new arrival is
    admitted to replica 1 in the same loop turn (per-replica admission: no
    fleet-wide barrier on one replica's prefill)."""
    eng, tp, dp = sharded_engine
    rt = _fleet(eng, tp, dp, n_rep=2, n_slots=1)
    rt.submit(Request(rid=0, prompt=_prompt(5, P=16), arrival_s=0.0, max_new=20))
    rt.submit(Request(rid=1, prompt=_prompt(6), arrival_s=1.0, max_new=4))
    rt.run()
    assert rt.replica_of(0) == 0 and rt.replica_of(1) == 1
    r0, r1 = rt.stats[0].records[0], rt.stats[1].records[1]
    # rid 1 was admitted while rid 0 was still decoding, not after it retired
    assert r1.admitted_s < r0.finish_s
