"""Fused KV-reorganization kernels (kernels/kv_moves.py) vs the index-based
reference (kernels/ref.kv_move_rows_ref) vs a numpy loop oracle.

The contract: byte-identical moves under parallel-assignment semantics for
overlapping src/dst windows, ``-1`` sources, duplicate masked destinations,
and empty plans; the non-donating variant never mutates its input (the async
snapshot/rollback contract of core/kv.py); and the whole engine — lockstep,
async commit AND async rollback, and 2-replica sharded serving — emits the
same bytes with the fused kernels enabled as the reference path does.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import kv as kvm
from repro.core.engine import SpecConfig, SpecEngine
from repro.flags import override_flags
from repro.kernels import ops
from repro.kernels.kv_moves import kv_move_rows_pallas, slot_write_rows_pallas
from repro.kernels.ref import kv_move_rows_ref
from repro.serving import Request, ShardedServingRuntime, VirtualClock


def _loop_oracle(arr, src, dst, mask):
    """Parallel assignment in numpy: all sources read before any write."""
    arr, src, dst, mask = map(np.asarray, (arr, src, dst, mask))
    out = arr.copy()
    act = mask & (src >= 0) & (dst >= 0)
    B, M = src.shape
    for b in range(B):
        for m in range(M):
            if act[b, m]:
                out[:, b, dst[b, m]] = arr[:, b, src[b, m]]
    return out


def _random_plan(rng, B, S, M):
    """Overlapping windows, -1 sources, duplicate destinations among masked
    rows (active destinations stay distinct, as MovePlan guarantees)."""
    src = rng.integers(0, S, size=(B, M)).astype(np.int32)
    src[rng.random((B, M)) < 0.2] = -1
    dst = np.stack([rng.permutation(S)[:M] for _ in range(B)]).astype(np.int32)
    mask = rng.random((B, M)) < 0.7
    # duplicate dsts allowed only where masked off: point them at a masked
    # twin's destination so the drop path is what keeps them out
    for b in range(B):
        off = np.where(~mask[b])[0]
        if len(off) >= 2:
            dst[b, off[0]] = dst[b, off[1]]
    return src, dst, mask


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_ref_matches_loop_oracle(seed):
    rng = np.random.default_rng(seed)
    U, B, S, F, M = 2, 3, 16, 5, 7
    arr = jnp.asarray(rng.normal(size=(U, B, S, F)), jnp.float32)
    src, dst, mask = _random_plan(rng, B, S, M)
    got = kv_move_rows_ref(arr, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), _loop_oracle(arr, src, dst, mask))


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_fused_matches_reference(seed):
    """Both kernel variants, interpret mode, byte-identical to the ref."""
    rng = np.random.default_rng(seed)
    U, B, S, F, M = 2, 2, 12, 4, 5
    arr = jnp.asarray(rng.normal(size=(U, B, S, F)), jnp.float32)
    src, dst, mask = _random_plan(rng, B, S, M)
    want = kv_move_rows_ref(arr, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask))
    active = jnp.asarray((mask & (src >= 0) & (dst >= 0)).astype(np.int32))
    for donate in (False, True):
        got = kv_move_rows_pallas(arr, jnp.asarray(src), jnp.asarray(dst), active,
                                  donate=donate, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_copy_through_preserves_input():
    """The non-donating variant is the zero-copy-snapshot keeper: the input
    buffer must be bit-unchanged after the call, even under jit."""
    rng = np.random.default_rng(0)
    arr = jnp.asarray(rng.normal(size=(1, 1, 8, 3)), jnp.float32)
    before = np.asarray(arr).copy()
    src = jnp.asarray([[0, 1]], jnp.int32)
    dst = jnp.asarray([[4, 5]], jnp.int32)
    act = jnp.ones((1, 2), jnp.int32)
    f = jax.jit(lambda a: kv_move_rows_pallas(a, src, dst, act, donate=False, interpret=True))
    out = f(arr)
    assert not np.array_equal(np.asarray(out), before)  # rows really moved
    np.testing.assert_array_equal(np.asarray(arr), before)  # snapshot intact


def test_empty_move_plans():
    """All-masked plans are no-ops; an M=0 plan short-circuits in ops."""
    rng = np.random.default_rng(1)
    arr = jnp.asarray(rng.normal(size=(2, 1, 6, 3)), jnp.float32)
    src = jnp.asarray([[2, -1]], jnp.int32)
    dst = jnp.asarray([[4, 4]], jnp.int32)
    none = jnp.zeros((1, 2), bool)
    for donate in (False, True):
        got = kv_move_rows_pallas(arr, src, dst, none.astype(jnp.int32),
                                  donate=donate, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(arr))
    np.testing.assert_array_equal(
        np.asarray(kv_move_rows_ref(arr, src, dst, none)), np.asarray(arr))
    empty = jnp.zeros((1, 0), jnp.int32)
    out = ops.kv_move_rows(arr, empty, empty, jnp.zeros((1, 0), bool))
    assert out is arr


def test_apply_moves_flag_paths_identical():
    """kv.apply_moves: fused and reference paths agree byte-for-byte on a
    cache pytree, and non-row leaves / "len" stay untouched on both."""
    rng = np.random.default_rng(2)
    S, M = 16, 6
    cache = {
        "len": jnp.asarray(3, jnp.int32),
        "groups": [({"k": jnp.asarray(rng.normal(size=(2, 1, S, 2, 3)), jnp.float32),
                     "v": jnp.asarray(rng.normal(size=(2, 1, S, 2, 3)), jnp.float32),
                     "ssm": jnp.full((2, 1, 4), 7.0)},)],
    }
    src, dst, mask = _random_plan(rng, 1, S, M)
    args = (jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask))
    ref = kvm.apply_moves(cache, *args)
    with override_flags(use_pallas_kv_moves=True, pallas_interpret=True):
        fused = kvm.apply_moves(cache, *args)
        fused_d = kvm.apply_moves(cache, *args, donate=True)
    for got in (fused, fused_d):
        for key in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(got["groups"][0][0][key]),
                np.asarray(ref["groups"][0][0][key]))
        np.testing.assert_array_equal(np.asarray(got["groups"][0][0]["ssm"]), 7.0)
        assert int(got["len"]) == 3


# ---------------------------------------------------------------------------
# slot lifecycle: one fused launch vs the per-leaf XLA path
# ---------------------------------------------------------------------------


def _toy_cache(rng, B, S):
    return {
        "len": jnp.zeros((), jnp.int32),
        "groups": [({"k": jnp.asarray(rng.normal(size=(2, B, S, 2, 3)), jnp.float32),
                     "v": jnp.asarray(rng.normal(size=(2, B, S, 2, 3)), jnp.float32),
                     "state": jnp.asarray(rng.normal(size=(1, B, 4)), jnp.float32)},)],
    }


def test_install_and_zero_slot_fused_match_xla():
    rng = np.random.default_rng(3)
    big, one = _toy_cache(rng, 3, 8), _toy_cache(rng, 1, 8)
    want_inst = kvm.install_slot(big, one, 1)
    want_zero = kvm.zero_slot(big, 2)
    with override_flags(use_pallas_kv_moves=True, pallas_interpret=True):
        got_inst = kvm.install_slot(big, one, 1)
        got_zero = kvm.zero_slot(big, 2)
    for got, want in ((got_inst, want_inst), (got_zero, want_zero)):
        for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_slot_write_rows_traced_slot_and_dtype_fallback():
    rng = np.random.default_rng(4)
    big, one = _toy_cache(rng, 3, 8), _toy_cache(rng, 1, 8)
    with override_flags(use_pallas_kv_moves=True, pallas_interpret=True):
        # traced slot: one jit covers every slot index (the engine contract)
        f = jax.jit(kvm.install_slot, donate_argnums=(0,))
        got = f(jax.tree.map(jnp.copy, big), one, jnp.asarray(2, jnp.int32))
        want = kvm.install_slot(big, one, 2)
        for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        # dtype mismatch: the fused kernel declines, the XLA path casts
        one16 = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16) if x.ndim > 0 else x, one)
        assert ops.slot_write_rows(
            jax.tree.leaves(big["groups"]), jax.tree.leaves(one16["groups"]), 0) is None
        got = kvm.install_slot(big, one16, 0)
        np.testing.assert_array_equal(
            np.asarray(got["groups"][0][0]["k"][:, 0]),
            np.asarray(one16["groups"][0][0]["k"][:, 0].astype(jnp.float32)))


def test_slot_write_rows_pallas_rejects_bad_leaves():
    a = jnp.zeros((2, 3, 4))
    with pytest.raises(ValueError):
        slot_write_rows_pallas([a], [jnp.zeros((2, 2, 4))], 0, interpret=True)
    with pytest.raises(ValueError):
        slot_write_rows_pallas([], [], 0, interpret=True)


# ---------------------------------------------------------------------------
# engine surfaces: fused path byte-identical to the reference path
# ---------------------------------------------------------------------------

ECFG = dict(bs=4, w=2, c=2, d=1, n_cap=16, mode="parallel", max_new=8)


def _prompt(k, P=8):
    return ((np.arange(1, P + 1) * k + 3) % 128).astype(np.int32)


@pytest.fixture(scope="module")
def fused_engines(dense_pair):
    T, D, tp, dp = dense_pair

    def mk(tgt, dr, **kw):
        return SpecEngine(tgt, dr, SpecConfig(**ECFG, **kw), S_max_t=256, S_max_d=256)

    return {"ref": mk(T, D), "fused": mk(T, D),
            "fused_self": mk(T, T), "async_self": mk(T, T, async_rounds=True),
            "sharded_ref": mk(T, D), "sharded_fused": mk(T, D)}, tp, dp


def test_solo_generate_fused_identical(fused_engines):
    e, tp, dp = fused_engines
    prompt = _prompt(3).reshape(1, -1)
    out_ref, _ = e["ref"].session(tp, dp).generate(prompt)
    with override_flags(use_pallas_kv_moves=True, pallas_interpret=True):
        out_fused, _ = e["fused"].session(tp, dp).generate(prompt)
    assert out_fused == out_ref


def test_async_commit_and_rollback_fused_identical(fused_engines):
    """The satellite regression: with the fused kernels on, the async
    pipeline's commit path (self-draft, lookahead adopted) AND the rollback
    path (sabotaged predictor, reconcile re-roots the retained snapshot)
    both stay byte-identical to lockstep — i.e. the copy-through kernel
    really preserved the snapshot and the donating kernel really moved the
    rows the reference would have."""
    e, tp, dp = fused_engines
    prompt = _prompt(5).reshape(1, -1)
    with override_flags(use_pallas_kv_moves=True, pallas_interpret=True):
        out_lock, _ = e["fused_self"].session(tp, tp).generate(prompt)
        asyn = e["async_self"]
        out_commit, st = asyn.session(tp, tp).generate(prompt)
        assert out_commit == out_lock
        assert st.spec_commits > 0, "commit path never exercised"
        real = asyn._predict
        try:  # force the rollback branch every round
            asyn._predict = lambda *a: (
                lambda p: (p[0], p[1], jnp.full_like(p[2], -1)))(real(*a))
            out_rb, st = asyn.session(tp, tp).generate(prompt)
        finally:
            asyn._predict = real
        assert out_rb == out_lock
        assert st.spec_rounds > 0 and st.spec_commits == 0


def test_sharded_serving_fused_identical(fused_engines):
    """2-replica sharded serving (slot install/zero through the fused
    single-launch writer, per-round moves through the fused kernels) emits
    exactly the reference fleet's bytes."""
    e, tp, dp = fused_engines
    reqs = [Request(rid=i, prompt=_prompt(i + 2), arrival_s=0.4 * i, max_new=6)
            for i in range(3)]

    def serve(eng):
        rt = ShardedServingRuntime([eng] * 2, tp, dp, n_slots=2, clock=VirtualClock())
        rt.submit_trace(Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s,
                                max_new=r.max_new) for r in reqs)
        return rt.run()

    ref = serve(e["sharded_ref"])
    with override_flags(use_pallas_kv_moves=True, pallas_interpret=True):
        fused = serve(e["sharded_fused"])
    assert fused == ref and sorted(fused) == [0, 1, 2]
