"""SLO-aware scheduling (repro.serving.scheduler + the queue/stats/runtime
wiring around it).

The contracts under test:

* per-slot adaptive draft depth never changes WHICH tokens a request emits
  — any depth schedule is byte-identical to solo ``generate()`` on every
  serving surface (direct session, continuous, sharded, async) — and adds
  ZERO jit traces (depth is a host loop count over the one jitted expand
  program);
* the queue's deadline-aware pop: EDF within a priority class, FIFO
  degeneration without deadlines, and the starvation bound;
* SLO accounting (attainment, slack percentiles) in ``summary()`` /
  ``merge_summary``, plus the serving-accounting bugfixes that rode along:
  rounds-weighted mean acceptance, nan-marked zero-round ratios rendered
  as ``-``, and tracer/round-in-flight hygiene when an absorb fails.
"""

import jax
import numpy as np
import pytest

from repro.core.engine import SpecConfig, SpecEngine, absorb_emitted
from repro.obs.trace import NOOP_SPAN, Tracer
from repro.serving import (
    AdaptiveDepthController,
    ContinuousBatchingRuntime,
    Request,
    RequestQueue,
    SchedulerConfig,
    ShardedServingRuntime,
    VirtualClock,
    merge_summary,
)
from repro.serving.stats import RequestRecord, ServerStats


@pytest.fixture(scope="module")
def sched_engine(dense_pair):
    T, D, tp, dp = dense_pair
    cfg = SpecConfig(bs=8, w=4, c=2, d=4, n_cap=64, mode="parallel", max_new=24)
    return SpecEngine(T, D, cfg, S_max_t=256, S_max_d=256), tp, dp


@pytest.fixture(scope="module")
def async_sched_engine(dense_pair):
    T, D, tp, dp = dense_pair
    cfg = SpecConfig(bs=8, w=4, c=2, d=4, n_cap=64, mode="parallel", max_new=24,
                     async_rounds=True)
    return SpecEngine(T, D, cfg, S_max_t=256, S_max_d=256), tp, dp


def _prompt(k, P=8):
    return ((np.arange(1, P + 1) * k + 3) % 128).astype(np.int32)


# ---------------------------------------------------------------------------
# SchedulerConfig / AdaptiveDepthController (pure host logic)
# ---------------------------------------------------------------------------


def test_config_validation():
    with pytest.raises(ValueError, match="ascending positive"):
        SchedulerConfig(depth_buckets=())
    with pytest.raises(ValueError, match="ascending positive"):
        SchedulerConfig(depth_buckets=(2, 2, 3))
    with pytest.raises(ValueError, match="ascending positive"):
        SchedulerConfig(depth_buckets=(0, 1))
    with pytest.raises(ValueError, match="thresholds"):
        SchedulerConfig(depth_buckets=(1, 2, 4), thresholds=(1.5,))
    with pytest.raises(ValueError, match="ema_alpha"):
        SchedulerConfig(ema_alpha=0.0)


def test_bucket_mapping_default_thresholds():
    # default cuts (1.0, 2.0, 3.0): draft roughly as deep as the measured
    # tokens/round; the boundary belongs to the deeper bucket
    cfg = SchedulerConfig()
    assert [cfg.bucket_for(x) for x in (0.0, 0.9, 1.0, 1.9, 2.5, 3.0, 9.0)] \
        == [1, 1, 2, 2, 3, 4, 4]
    custom = SchedulerConfig(depth_buckets=(2, 4), thresholds=(2.5,))
    assert custom.bucket_for(2.4) == 2 and custom.bucket_for(2.5) == 4


def test_clamp_picks_nearest_bucket_ties_shallow():
    cfg = SchedulerConfig(depth_buckets=(1, 2, 4))
    assert cfg.clamp(0) == 1
    assert cfg.clamp(9) == 4
    assert cfg.clamp(3) == 2  # equidistant from 2 and 4: the cheaper round


def test_controller_ema_round_depth_and_lifecycle():
    ctl = AdaptiveDepthController(SchedulerConfig(ema_alpha=0.5), 3,
                                  default_depth=4)
    # no measurements anywhere: the engine's configured depth
    assert ctl.round_depth([True, True, False]) == 4
    ctl.seed_slot(0)  # no histogram, no explicit seed -> still no prior
    assert ctl.slot_ema(0) is None
    ctl.observe(0, 1)  # first observation adopts the measurement outright
    assert ctl.slot_ema(0) == 1.0
    ctl.observe(0, 0)
    assert ctl.slot_ema(0) == pytest.approx(0.5)
    assert ctl.slot_depth(0) == 1
    ctl.observe(1, 4)  # slot 1 accepts deeply
    assert ctl.slot_depth(1) == 4
    # the round runs at the max over OCCUPIED slots only
    assert ctl.round_depth([True, False, False]) == 1
    assert ctl.round_depth([True, True, False]) == 4
    # retire slot 1: its history must not leak into the next occupant
    ctl.clear_slot(1)
    assert ctl.slot_ema(1) is None
    assert ctl.round_depth([True, True, False]) == 4  # back to default for 1


def test_controller_seeding_priority():
    class _Hist:
        count, mean = 12, 3.2

    explicit = AdaptiveDepthController(
        SchedulerConfig(seed_acceptance=0.5), 1, default_depth=4,
        seed_hist=_Hist())
    explicit.seed_slot(0)
    assert explicit.slot_ema(0) == 0.5  # explicit seed beats the histogram
    warm = AdaptiveDepthController(SchedulerConfig(), 1, default_depth=4,
                                   seed_hist=_Hist())
    warm.seed_slot(0)
    assert warm.slot_ema(0) == pytest.approx(3.2)  # histogram mean
    assert warm.slot_depth(0) == 4


# ---------------------------------------------------------------------------
# deadline-aware queue pop
# ---------------------------------------------------------------------------


def _req(rid, arrival=0.0, deadline=None, priority=0):
    return Request(rid=rid, prompt=_prompt(rid + 1), arrival_s=arrival,
                   deadline_s=deadline, priority=priority)


def test_edf_pop_orders_by_deadline_then_fifo():
    q = RequestQueue()
    q.submit(_req(0, deadline=9.0))
    q.submit(_req(1, deadline=3.0))
    q.submit(_req(2))  # best-effort: after any deadline
    q.submit(_req(3, deadline=3.0))  # ties with rid 1: FIFO
    assert [q.pop_ready(0.0).rid for _ in range(4)] == [1, 3, 0, 2]


def test_priority_classes_dominate_deadlines():
    q = RequestQueue()
    q.submit(_req(0, deadline=1.0, priority=1))  # tightest, but batch class
    q.submit(_req(1, deadline=50.0, priority=0))
    q.submit(_req(2, priority=0))
    assert [q.pop_ready(0.0).rid for _ in range(3)] == [1, 2, 0]


def test_pop_is_exact_fifo_without_deadlines():
    q = RequestQueue()
    for i in range(5):
        q.submit(_req(i))
    assert [q.pop_ready(0.0).rid for _ in range(5)] == [0, 1, 2, 3, 4]


def test_edf_respects_arrival_gating():
    q = RequestQueue()
    q.submit(_req(0, arrival=0.0, deadline=50.0))
    q.submit(_req(1, arrival=5.0, deadline=1e-9 + 5.0))  # tight but future
    assert q.pop_ready(0.0).rid == 0  # rid 1 has not arrived yet
    assert q.pop_ready(0.0) is None


def test_starvation_bound_overrides_edf():
    q = RequestQueue(starvation_s=4.0)
    q.submit(_req(0))  # best-effort, oldest
    q.submit(_req(1, deadline=2.0))
    q.submit(_req(2, deadline=3.0))
    assert q.pop_ready(1.0).rid == 1  # EDF while nobody is starving
    # at t=4 the best-effort head has waited >= starvation_s: it jumps
    assert q.pop_ready(4.0).rid == 0
    assert q.pop_ready(4.0).rid == 2


def test_deadline_before_arrival_rejected():
    with pytest.raises(ValueError, match="deadline_s"):
        Request(rid=0, prompt=_prompt(1), arrival_s=2.0, deadline_s=1.0)


def test_starvation_s_validated():
    with pytest.raises(ValueError, match="starvation_s"):
        RequestQueue(starvation_s=0.0)


# ---------------------------------------------------------------------------
# byte-identity: adaptive depth changes WHEN tokens verify, never WHICH
# ---------------------------------------------------------------------------


def test_depth_schedule_byte_identity_direct_session(sched_engine):
    """Driving the session with a wildly varying per-round depth emits the
    exact solo-generate stream (greedy verification pins it)."""
    eng, tp, dp = sched_engine
    prompt = _prompt(3).reshape(1, -1)
    solo, _ = eng.generate(tp, dp, prompt, max_new=16)

    ses = eng.session(tp, dp)
    ses.state = eng._prefill_state(tp, dp, prompt)
    out, done, schedule = [], False, [1, 4, 2, 1, 3, 4, 1, 2]
    for i in range(40):
        if done:
            break
        res = ses.step(depth=schedule[i % len(schedule)])
        _, done = absorb_emitted(out, res.emitted[0], res.n_emitted[0], 16,
                                 eng.cfg.eos_id)
    assert out == solo[0]


def test_depth_variation_adds_no_jit_traces(sched_engine):
    """Depth is a host loop trip count over ONE jitted expand program: after
    warmup, running every bucket adds zero entries to its jit cache."""
    eng, tp, dp = sched_engine
    ses = eng.session(tp, dp)
    ses.state = eng._prefill_state(tp, dp, _prompt(5).reshape(1, -1))
    ses.step(depth=4)  # warm every program at the deepest bucket
    baseline = eng._expand._cache_size()
    for d in (1, 2, 3, 4, 2, 1):
        ses.step(depth=d)
    assert eng._expand._cache_size() == baseline


@pytest.mark.parametrize("surface", ["continuous", "sharded", "async"])
def test_adaptive_depth_byte_identity_serving(surface, sched_engine,
                                              async_sched_engine):
    """Adaptive scheduling on: staggered deadlined+best-effort traffic over
    recycled slots still emits solo-identical streams on every surface."""
    eng, tp, dp = async_sched_engine if surface == "async" else sched_engine
    sched = SchedulerConfig(ema_alpha=0.5)
    reqs = [Request(rid=i, prompt=_prompt(i + 1, P=8 + 4 * (i % 2)),
                    arrival_s=0.7 * i, max_new=16,
                    deadline_s=0.7 * i + 40.0 if i % 2 else None)
            for i in range(5)]
    if surface == "sharded":
        rt = ShardedServingRuntime([eng, eng], tp, dp, n_slots=2,
                                   clock=VirtualClock(), scheduler=sched)
    else:
        rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=2,
                                       clock=VirtualClock(), scheduler=sched)
    assert rt.submit_trace(reqs) == 5
    results = rt.run()
    assert sorted(results) == [0, 1, 2, 3, 4]
    for r in reqs:
        solo, _ = eng.generate(tp, dp, r.prompt.reshape(1, -1), max_new=16)
        assert results[r.rid] == solo[0], \
            f"request {r.rid} diverged from solo generate() on {surface}"
    # the controller actually adapted: the round-depth series exists and
    # every recorded depth is an admissible bucket
    depths = {v for _, s in rt.metrics.series_family("serving_round_depth")
              for _, v in s.samples}
    assert depths and depths <= set(sched.depth_buckets)


def test_adaptive_depth_reduces_round_cost_on_virtual_clock(sched_engine):
    """With the per-expansion cost model, a low-acceptance workload finishes
    the same byte-identical stream in less virtual time under adaptive depth
    than at the fixed global d=4 (shallower rounds are cheaper)."""
    eng, tp, dp = sched_engine

    def _serve(scheduler):
        rt = ContinuousBatchingRuntime(
            eng, tp, dp, n_slots=2,
            clock=VirtualClock(round_dt=1.0, expand_dt=0.25),
            scheduler=scheduler)
        rt.submit_trace(Request(rid=i, prompt=_prompt(i + 2), arrival_s=0.0,
                                max_new=16) for i in range(4))
        res = rt.run()
        return res, rt.clock.now()

    fixed_res, fixed_t = _serve(None)
    # force-shallow schedule stands in for "adaptation found depth 1 pays":
    # identical tokens, strictly cheaper rounds on the expand_dt cost model
    adapt_res, adapt_t = _serve(SchedulerConfig(depth_buckets=(1,)))
    assert adapt_res == fixed_res
    assert adapt_t < fixed_t


# ---------------------------------------------------------------------------
# SLO accounting
# ---------------------------------------------------------------------------


def test_summary_slo_fields_and_report(sched_engine):
    """One generous and one impossible deadline: attainment is 1/2, slack
    percentiles are finite, the report tags the late row and appends the
    SLO aggregate."""
    eng, tp, dp = sched_engine
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=2, clock=VirtualClock())
    rt.submit(Request(rid=0, prompt=_prompt(1), max_new=8, deadline_s=500.0))
    rt.submit(Request(rid=1, prompt=_prompt(2), max_new=8, deadline_s=1e-6))
    rt.submit(Request(rid=2, prompt=_prompt(3), max_new=8))  # best-effort
    rt.run()
    s = rt.stats.summary()
    assert s["n_deadlined"] == 2
    assert s["slo_attainment"] == pytest.approx(0.5)
    assert np.isfinite(s["slack_p50_s"]) and np.isfinite(s["slack_p10_s"])
    assert rt.stats.records[0].met_deadline is True
    assert rt.stats.records[1].met_deadline is False
    assert rt.stats.records[2].met_deadline is None
    rep = rt.stats.report()
    assert "LATE" in rep and "SLO 50%" in rep


def test_merge_summary_slo_over_fleet():
    def _stats(finishes):
        st = ServerStats()
        for rid, (deadline, finish) in enumerate(finishes):
            st.records[rid] = RequestRecord(
                rid=rid, deadline_s=deadline, finish_s=finish,
                n_rounds=1, n_accepted=1, n_tokens=1)
        return st

    a = _stats([(10.0, 5.0), (10.0, 12.0)])  # met, missed
    b = _stats([(None, 3.0), (4.0, 4.0)])  # best-effort, met exactly
    s = merge_summary([a, b])
    assert s["n_deadlined"] == 3
    assert s["slo_attainment"] == pytest.approx(2 / 3)
    assert s["slack_p50_s"] == pytest.approx(0.0)  # slacks: +5, -2, 0
    # a fleet with no deadlines anywhere nan-marks attainment (no SLO to
    # attain), and the empty fleet keeps mean_acceptance == 0.0 (legacy)
    empty = merge_summary([])
    assert empty["n_deadlined"] == 0 and empty["slo_attainment"] != empty["slo_attainment"]
    assert empty["mean_acceptance"] == 0.0


# ---------------------------------------------------------------------------
# serving-accounting bugfixes
# ---------------------------------------------------------------------------


def test_mean_acceptance_is_rounds_weighted():
    """A 1-round request must not count as much as a 100-round request:
    mean acceptance is total accepted over total rounds, not a mean of
    per-request ratios."""
    st = ServerStats()
    st.records[0] = RequestRecord(rid=0, n_rounds=1, n_accepted=1,
                                  n_tokens=2, finish_s=1.0)
    st.records[1] = RequestRecord(rid=1, n_rounds=100, n_accepted=300,
                                  n_tokens=400, finish_s=1.0)
    got = st.summary()["mean_acceptance"]
    assert got == pytest.approx(301 / 101)
    assert got != pytest.approx((1.0 + 3.0) / 2)  # the old unweighted bias
    assert merge_summary([st])["mean_acceptance"] == pytest.approx(301 / 101)


def test_zero_round_ratios_are_nan_and_render_as_dash():
    r = RequestRecord(rid=0, n_rounds=0, n_accepted=0, finish_s=1.0)
    assert r.acceptance != r.acceptance  # nan, not a fake 0.0
    assert r.compression_ratio != r.compression_ratio
    st = ServerStats()
    st.records[0] = r
    rep = st.report()
    assert "nan" not in rep
    assert " - " in rep or "-" in rep.splitlines()[1]
    # records with rounds are unaffected; a zero-round record contributes
    # weight 0 instead of poisoning the aggregate with nan
    st.records[1] = RequestRecord(rid=1, n_rounds=4, n_accepted=6, finish_s=1.0)
    assert st.summary()["mean_acceptance"] == pytest.approx(6 / 4)


@pytest.mark.parametrize("mode", ["lockstep", "async"])
def test_failing_absorb_leaves_tracer_balanced_and_session_quiescent(
        mode, sched_engine, async_sched_engine):
    """An absorb that raises (poisoned stream callback) must end the round
    span (tracer balanced) and leave no RoundInFlight orphaned — the fleet
    loop aborts the round on the way out and the session stays usable."""
    eng, tp, dp = async_sched_engine if mode == "async" else sched_engine
    tracer = Tracer(clock=lambda: 0.0)

    def bad_stream(rid, toks, done):
        raise RuntimeError("poisoned stream")

    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=2,
                                   clock=VirtualClock(), tracer=tracer,
                                   stream=bad_stream)
    rt.submit(Request(rid=0, prompt=_prompt(1), max_new=8))
    rt.submit(Request(rid=1, prompt=_prompt(2), max_new=8))
    with pytest.raises(RuntimeError, match="poisoned stream"):
        rt.run()
    # the round span was closed on the failure path, not leaked open
    assert rt.stepper._round_span is NOOP_SPAN
    rounds = tracer.spans("round")
    assert rounds and all(s.t1 is not None for s in rounds)
    # no orphaned RoundInFlight: the session is quiescent and steppable
    assert rt.stepper.session._inflight is None
    rt.stepper.session._check_quiescent("test")  # does not raise
    res = rt.stepper.step()
    rt.stepper.abort_round(res)  # abort path itself is balanced too
    assert rt.stepper._round_span is NOOP_SPAN
