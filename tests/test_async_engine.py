"""Async round disaggregation (``SpecConfig.async_rounds``).

The contract under test: the pipelined dispatch_verify / draft_next_tree /
reconcile path changes WHEN draft work happens (round N+1's tree is drafted
while round N verifies), never which tokens verify emits — so at temperature
0 every surface (solo generate, continuous batching, the 2-replica sharded
fleet) is byte-identical to the lockstep path, whether the lookahead seed
commits or is rolled back.  Plus the reconcile rollback itself: a forced
rejected seed must take the snapshot + re-root path and still emit the
lockstep bytes, and the traced async run must show draft work genuinely
overlapping the open verify window (lockstep shows exactly zero).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import SpecConfig, SpecEngine, SpecStats
from repro.obs import Tracer, phase_breakdown
from repro.serving import (
    ContinuousBatchingRuntime,
    Request,
    ShardedServingRuntime,
    VirtualClock,
)

CFG = dict(bs=8, w=4, c=2, d=2, n_cap=64, mode="parallel", max_new=24)


def _prompt(k, P=8):
    return ((np.arange(1, P + 1) * k + 3) % 128).astype(np.int32)


@pytest.fixture(scope="module")
def engines(dense_pair):
    """Lockstep/async engine pairs: self-draft (draft == target, so the
    lookahead seed should usually commit) and independent-draft (tiny random
    draft disagrees with the target, so reconcile runs every round)."""
    T, D, tp, dp = dense_pair

    def mk(tgt, dr, **kw):
        return SpecEngine(tgt, dr, SpecConfig(**CFG, **kw),
                          S_max_t=256, S_max_d=256)

    return {
        "lock_self": mk(T, T), "async_self": mk(T, T, async_rounds=True),
        "lock_td": mk(T, D), "async_td": mk(T, D, async_rounds=True),
    }, tp, dp


def test_async_requires_parallel_mode(dense_pair):
    T, D, *_ = dense_pair
    with pytest.raises(ValueError, match="async_rounds"):
        SpecEngine(T, D, SpecConfig(**{**CFG, "mode": "serial"},
                                    async_rounds=True),
                   S_max_t=256, S_max_d=256)


# ---------------------------------------------------------------------------
# solo generate: commit path and fallback path both byte-identical
# ---------------------------------------------------------------------------


def test_solo_self_draft_identical_and_commits(engines):
    """Self-draft: predictions hold, so the pre-drafted lookahead tree is
    adopted (spec_commits > 0) and outputs still equal lockstep exactly."""
    e, tp, dp = engines
    prompt = _prompt(3).reshape(1, -1)
    out_lock, _ = e["lock_self"].session(tp, tp).generate(prompt)
    out_async, st = e["async_self"].session(tp, tp).generate(prompt)
    assert out_async == out_lock
    assert st.spec_rounds == st.rounds > 0
    assert st.spec_commits > 0, "self-draft lookahead seed never committed"


def test_solo_independent_draft_identical(engines):
    """Independent tiny draft: the target disagrees, the seed is rejected,
    reconcile rolls back every round — bytes still equal lockstep."""
    e, tp, dp = engines
    prompt = _prompt(5).reshape(1, -1)
    out_lock, _ = e["lock_td"].session(tp, dp).generate(prompt)
    out_async, st = e["async_td"].session(tp, dp).generate(prompt)
    assert out_async == out_lock
    assert st.spec_rounds == st.rounds > 0


def test_forced_rejection_every_round_still_identical(engines):
    """Sabotage the predictor so the seed can never match (a real bonus
    token is always >= 0): every round must take the rollback path and the
    output must not change by a byte."""
    e, tp, dp = engines
    eng = e["async_self"]
    prompt = _prompt(7).reshape(1, -1)
    out_lock, _ = e["lock_self"].session(tp, tp).generate(prompt)

    real = eng._predict
    try:
        eng._predict = lambda *a: (lambda p: (p[0], p[1], jnp.full_like(p[2], -1)))(real(*a))
        out_async, st = eng.session(tp, tp).generate(prompt)
    finally:
        eng._predict = real
    assert out_async == out_lock
    assert st.spec_rounds > 0 and st.spec_commits == 0


# ---------------------------------------------------------------------------
# reconcile unit test: a rejected lookahead seed, forced at the RoundInFlight
# ---------------------------------------------------------------------------


def test_reconcile_rolls_back_rejected_seed(engines):
    """Drive the phase API by hand against a lockstep twin: tamper each
    round's prediction so reconcile MUST reject the lookahead and re-root
    from the retained snapshot — per-round results stay identical."""
    e, tp, dp = engines
    lock, asyn = e["lock_self"], e["async_self"]
    prompt = _prompt(4).reshape(1, -1)
    ref = lock.session(tp, tp)
    ref.state = lock._prefill_state(tp, tp, prompt)
    sess = asyn.session(tp, tp)
    sess.state = asyn._prefill_state(tp, tp, prompt)

    for _ in range(3):
        rif = sess.begin_round()
        pa, pn, pb = rif.pred
        rif.pred = (pa, pn, jnp.full_like(pb, -1))  # seed can never match
        st = SpecStats()
        got = sess.reconcile(rif, stats=st)
        assert st.spec_commits == 0  # the rollback branch really ran
        want = ref.step()
        np.testing.assert_array_equal(got.n_emitted, want.n_emitted)
        np.testing.assert_array_equal(got.n_accepted, want.n_accepted)
        np.testing.assert_array_equal(got.emitted, want.emitted)


def test_dispatch_while_in_flight_is_an_error(engines):
    """The donated-state discipline: a second dispatch (or admit/release)
    before reconcile must fail loudly, not corrupt the round."""
    e, tp, dp = engines
    sess = e["async_self"].session(tp, tp, n_slots=1)
    sess.admit_slot(0, _prompt(2))
    rif = sess.begin_round()
    with pytest.raises(RuntimeError, match="in flight"):
        sess.dispatch_verify()
    with pytest.raises(RuntimeError, match="in flight"):
        sess.admit_slot(0, _prompt(3))
    sess.reconcile(rif)  # leave the module-scoped fixture quiescent


# ---------------------------------------------------------------------------
# serving: continuous and 2-replica sharded, byte-identical to lockstep
# ---------------------------------------------------------------------------


def _serve(rt, reqs):
    rt.submit_trace(Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s,
                            max_new=r.max_new) for r in reqs)
    return rt.run()


def test_continuous_async_matches_lockstep(engines):
    e, tp, dp = engines
    reqs = [Request(rid=i, prompt=_prompt(i + 1, P=8 + 4 * (i % 2)),
                    arrival_s=0.5 * i, max_new=12) for i in range(4)]
    lock = _serve(ContinuousBatchingRuntime(
        e["lock_self"], tp, tp, n_slots=2, clock=VirtualClock()), reqs)
    asy = _serve(ContinuousBatchingRuntime(
        e["async_self"], tp, tp, n_slots=2, clock=VirtualClock()), reqs)
    assert asy == lock and sorted(asy) == [0, 1, 2, 3]


def test_sharded_async_matches_lockstep(engines):
    e, tp, dp = engines
    reqs = [Request(rid=i, prompt=_prompt(i + 2), arrival_s=0.4 * i, max_new=10)
            for i in range(4)]
    lock = _serve(ShardedServingRuntime(
        [e["lock_td"]] * 2, tp, dp, n_slots=2, clock=VirtualClock()), reqs)
    asy = _serve(ShardedServingRuntime(
        [e["async_td"]] * 2, tp, dp, n_slots=2, clock=VirtualClock()), reqs)
    assert asy == lock and sorted(asy) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# the trace proves the pipeline: draft under the open verify window
# ---------------------------------------------------------------------------


def test_traced_overlap_async_nonzero_lockstep_zero(engines):
    e, tp, dp = engines
    reqs = [Request(rid=i, prompt=_prompt(i + 1), arrival_s=0.0, max_new=10)
            for i in range(2)]
    bds = {}
    for key in ("lock_self", "async_self"):
        tracer = Tracer()
        _serve(ContinuousBatchingRuntime(
            e[key], tp, tp, n_slots=2, clock=VirtualClock(), tracer=tracer), reqs)
        bds[key] = phase_breakdown(tracer)
    lock, asy = bds["lock_self"], bds["async_self"]
    assert lock["overlap_draft_verify_s"] == 0.0
    # structural overlap assertions are deterministic; the hard >=0.95
    # coverage gate lives in test_obs + the CI smoke, where rounds are long
    # enough not to flake under CPU contention — here just sanity-check it
    assert lock["coverage_mean"] > 0.5 and asy["coverage_mean"] > 0.5
    assert asy["overlap_draft_verify_s"] > 0.0
    assert asy["phase_s"]["draft_lookahead"] > 0.0
    # the whole point: less draft time serialized on the critical path
    assert asy["draft_serialized_frac"] < asy["draft_frac"]
