"""Paper Tables 3 + 7 — per-operator utilization and fused-kernel times.

Regime: DERIVED (per-op roofline with v5e constants) + MEASURED correctness
(interpret-mode kernels are validated against oracles in tests/test_kernels.py;
wall-clock of the Python interpreter is meaningless, so times here come from
the data-movement model that the fusions actually change).

What fusion changes on TPU (DESIGN.md §3):
  GEMM+AR     — unfused: GEMM writes partial to HBM, AR reads+writes it, plus
                a dispatch+latency floor per op.  Fused/collective-matmul: one
                pass, transfer overlapped, one floor.
  splitkv attn— unfused (FA-style): partial (max,sum,acc) triples to HBM +
                second combine kernel.  Ours: sequential-grid accumulate in
                VMEM, single kernel.
  SwiGLU      — unfused: x read twice, g/u round-trip HBM.  Fused: x once,
                epilogue in-register.
"""

from __future__ import annotations

from repro.configs import get_config

from benchmarks.common import (AR_BASE, HBM_BW, ICI_HOP, LINK_BW, OP_OVERHEAD,
                               PEAK_FLOPS, write_csv, write_json)

BS = 8
CONFIGS = [("llama3-1b", 4), ("llama3-3b", 4), ("llama3-8b", 4), ("llama3-70b", 4), ("llama3-70b", 8)]


def _gemm_time(m, k, n, tp, weight_bytes=0.5):
    """one weight-sharded GEMM: weights dominate HBM traffic at bs<=16."""
    t_mem = (k * n / tp) * weight_bytes / HBM_BW
    t_fl = 2 * m * k * n / tp / PEAK_FLOPS
    return max(t_mem, t_fl)


def _ar_time(nbytes, tp):
    return AR_BASE + 2 * (tp - 1) * ICI_HOP + nbytes * (tp - 1) / tp / LINK_BW


def kernel_rows(cfg, tp, context=500):
    d, ff = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    act = 2.0  # bf16
    rows = []

    # --- fused GEMM + all-reduce (attn o-proj and mlp down-proj) ----------
    for tag, (k_dim, n_dim) in (("attn", (hq * hd, d)), ("mlp", (ff, d))):
        t_gemm = _gemm_time(BS, k_dim, n_dim, tp)
        t_ar = _ar_time(BS * d * act, tp)
        unfused = t_gemm + OP_OVERHEAD + t_ar + OP_OVERHEAD + 2 * BS * d * act / HBM_BW
        fused = max(t_gemm, t_ar) + OP_OVERHEAD  # transfer rides the GEMM
        rows.append([cfg.name, tp, f"fused_gemm_ar_{tag}", round(unfused * 1e6, 2),
                     round(fused * 1e6, 2), round(unfused / fused, 2)])

    # --- attention: split-KV single kernel vs two-kernel combine ----------
    kv_bytes = 2 * context * hkv * hd * act / tp
    t_core = max(kv_bytes / HBM_BW, 4 * BS * context * hq * hd / tp / PEAK_FLOPS)
    n_splits = 4
    partial_bytes = n_splits * BS * hq * hd * 4 * 3 / tp  # (max,sum,acc) f32
    unfused = t_core + OP_OVERHEAD + 2 * partial_bytes / HBM_BW + OP_OVERHEAD
    fused = t_core + OP_OVERHEAD
    rows.append([cfg.name, tp, f"attn_ctx{context}", round(unfused * 1e6, 2),
                 round(fused * 1e6, 2), round(unfused / fused, 2)])

    # --- SwiGLU ------------------------------------------------------------
    t_w = 2 * d * ff / tp * 0.5 / HBM_BW  # wg+wu int4
    t_x2 = 2 * BS * d * act / HBM_BW  # x read twice
    t_gu = 4 * BS * ff / tp * act / HBM_BW  # g,u round trip
    t_fl = 2 * 2 * BS * d * ff / tp / PEAK_FLOPS
    unfused = max(t_w + t_x2 + t_gu, t_fl) + 3 * OP_OVERHEAD
    fused = max(t_w + t_x2 / 2, t_fl) + OP_OVERHEAD
    rows.append([cfg.name, tp, "swiglu", round(unfused * 1e6, 2),
                 round(fused * 1e6, 2), round(unfused / fused, 2)])

    # --- KV reorganization (paper §3.2): fused O(M) row moves --------------
    dense_b, fused_b = kv_reorg_bytes(cfg, tp, context=context)
    unfused = dense_b / HBM_BW + 2 * OP_OVERHEAD  # gather pass + scatter pass
    fused = fused_b / HBM_BW + OP_OVERHEAD  # one launch, moved rows only
    rows.append([cfg.name, tp, f"kv_reorg_ctx{context}", round(unfused * 1e6, 2),
                 round(fused * 1e6, 2), round(unfused / fused, 2)])
    return rows


def kv_reorg_bytes(cfg, tp, context=500, moved=BS):
    """Modeled HBM traffic of one per-round cache reorganization (verify
    compaction / draft re-root, core/kv.apply_moves): the one-hot einsum
    formulation reads AND rewrites the whole [B, S, F] cache for both the
    gather and the scatter pass, O(B·S·F) that scales with context; the
    fused kv_move_rows kernel DMAs only the M ≈ bs moved rows per batch
    element, O(B·M·F) (kernels/kv_moves.py).  Returns (dense, fused) bytes
    per move across the k+v leaves of every layer."""
    hkv, hd, act = cfg.n_kv_heads, cfg.head_dim, 2.0  # bf16 rows
    row_bytes = 2 * hkv * hd * act * cfg.n_layers / tp  # k+v, all layers
    dense = 2 * 2 * BS * context * row_bytes  # 2 passes x (read + write) x S
    fused = 2 * BS * moved * row_bytes  # read + write of M rows
    return dense, fused


def utilization_rows(cfg, tp, context=500):
    """Paper Table 3: per-op bandwidth/compute utilization at bs=8 — the
    'everything is latency-bound' observation."""
    d, ff, hq, hkv, hd = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = []
    ops = {
        "qkv_proj": (_gemm_time(BS, d, (hq + 2 * hkv) * hd, tp),
                     d * (hq + 2 * hkv) * hd / tp * 0.5, 2 * BS * d * (hq + 2 * hkv) * hd / tp),
        "attention": (max(2 * context * hkv * hd * 2.0 / tp / HBM_BW, 1e-6) + OP_OVERHEAD,
                      2 * context * hkv * hd * 2.0 / tp, 4 * BS * context * hq * hd / tp),
        "o_proj": (_gemm_time(BS, hq * hd, d, tp), hq * hd * d / tp * 0.5, 2 * BS * hq * hd * d / tp),
        "all_reduce": (_ar_time(BS * d * 2.0, tp), BS * d * 2.0, 0),
        "swiglu": (_gemm_time(BS, d, 2 * ff, tp), 2 * d * ff / tp * 0.5, 4 * BS * d * ff / tp),
        "down_proj": (_gemm_time(BS, ff, d, tp), ff * d / tp * 0.5, 2 * BS * ff * d / tp),
    }
    for name, (t, nbytes, flops) in ops.items():
        t = t + OP_OVERHEAD
        bw_util = nbytes / t / (LINK_BW if name == "all_reduce" else HBM_BW)
        fl_util = flops / t / PEAK_FLOPS
        out.append([cfg.name, tp, name, round(t * 1e6, 2), round(100 * fl_util, 2),
                    round(100 * bw_util, 1)])
    return out


def run():
    rows7, rows3 = [], []
    for name, tp in CONFIGS:
        cfg = get_config(name)
        rows7 += kernel_rows(cfg, tp)
    cfg70 = get_config("llama3-70b")
    rows3 += utilization_rows(cfg70, tp=4)

    p7 = write_csv("table7_kernel_micro.csv",
                   ["model", "tp", "kernel", "unfused_us", "fused_us", "speedup"], rows7)
    p3 = write_csv("table3_op_utilization.csv",
                   ["model", "tp", "op", "time_us", "compute_util_%", "bandwidth_util_%"], rows3)

    import collections
    by_kernel = collections.defaultdict(list)
    for r in rows7:
        by_kernel[r[2].split("_ctx")[0]].append(r[5])
    for k, v in by_kernel.items():
        print(f"  {k:22s} mean fusion speedup {sum(v)/len(v):.2f}x over {len(v)} configs")
    # Table 3, TPU-adapted: on H800 EVERY op is latency-bound at bs=8 (<50%
    # util) because of per-kernel launches + NCCL sync.  On TPU the weight
    # GEMMs saturate HBM (one fused program, 4x lower BW than H800), while
    # attention and all-reduce REMAIN latency-bound — they are exactly the
    # ops our fused kernels attack.
    util = {r[2]: r[5] for r in rows3}
    assert util["attention"] < 30 and util["all_reduce"] < 30, util
    assert util["qkv_proj"] > 60 and util["down_proj"] > 60, util
    print(f"  TPU adaptation: GEMMs HBM-saturated ({util['qkv_proj']:.0f}%/{util['down_proj']:.0f}%), "
          f"attention/all-reduce latency-bound ({util['attention']:.0f}%/{util['all_reduce']:.0f}%); {p3}")

    # KV-reorg traffic: the O(B·S·F) -> O(B·M·F) drop, quantified per config
    # (the ratio is context/moved: traffic no longer scales with context)
    reorg = []
    for name, tp in CONFIGS:
        cfg = get_config(name)
        for context in (500, 2000, 8000):
            dense_b, fused_b = kv_reorg_bytes(cfg, tp, context=context)
            reorg.append({"model": name, "tp": tp, "context": context,
                          "moved_rows": BS, "dense_onehot_bytes": int(dense_b),
                          "fused_bytes": int(fused_b),
                          "traffic_ratio": round(dense_b / fused_b, 1)})
            assert fused_b < dense_b, (name, context)
    pkv = write_json("kv_reorg_traffic.json", {"rows": reorg})
    worst = min(r["traffic_ratio"] for r in reorg)
    print(f"  kv_reorg: fused moves {BS} rows instead of 2 dense cache passes "
          f"(traffic ratio {worst:.0f}x at ctx500, grows with context); {pkv}")
    return p7


if __name__ == "__main__":
    run()
