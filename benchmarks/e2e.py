"""Paper Figure 7 — end-to-end decoding speed across five model pairs and
four system configurations (SwiftSpec vs the serial/unfused baselines).

Regime: MEASURED dynamics + DERIVED schedule.  Per-pair compression ratios
(serial and parallel) are measured with the real engine on smoke models of
the same family; round times come from the roofline model of the PAPER's
actual pairs under their best allocations.  The four configurations mirror
Figure 8's ablation grid, so this benchmark doubles as its data source:

  swiftspec            parallel tree generation + fused kernels
  only-parallel-tree   parallel tree generation, unfused kernels
  only-kernel-opt      serial speculation, fused kernels
  swiftspec-base       serial speculation, unfused kernels
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import SpecConfig, SpecEngine

from benchmarks.common import build_pair, infer_time_model, write_csv

# the paper's five pairs (public configs, outer shapes)
PAIRS = {
    "llama3-70b/3.2-3b": (
        ModelConfig(name="llama3-70b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                    d_ff=28672, vocab_size=128256),
        ModelConfig(name="llama3.2-3b", n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
                    d_ff=8192, vocab_size=128256),
        "qwen2.5-14b",
    ),
    "dscoder-33b/1.3b": (
        ModelConfig(name="dscoder-33b", n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
                    d_ff=19200, vocab_size=32256),
        ModelConfig(name="dscoder-1.3b", n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
                    d_ff=5504, vocab_size=32256),
        "deepseek-coder-33b",
    ),
    "qwen2-72b/1.5b": (
        ModelConfig(name="qwen2-72b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                    d_ff=29568, vocab_size=152064, qkv_bias=True),
        ModelConfig(name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                    d_ff=8960, vocab_size=151936, qkv_bias=True),
        "qwen2.5-14b",
    ),
    "r1-qwen-32b/1.5b": (
        ModelConfig(name="r1-qwen-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
                    d_ff=27648, vocab_size=152064, qkv_bias=True),
        ModelConfig(name="r1-qwen-1.5b", n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                    d_ff=8960, vocab_size=151936, qkv_bias=True),
        "qwen2.5-14b",
    ),
    "r1-llama-70b/8b": (
        ModelConfig(name="r1-llama-70b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                    d_ff=28672, vocab_size=128256),
        ModelConfig(name="r1-llama-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
                    d_ff=14336, vocab_size=128256),
        "granite-20b",
    ),
}

KERNEL_SPEEDUP = 1.18  # per-inference gain from the fused kernels (Table 7 mean
# over the latency-bound ops; the paper's ablation sees 1.16-1.21x end-to-end)
SYNC = 20e-6


def measured_ratios(smoke_arch: str, d: int, peak: float = 4.0):
    cfgT, cfgD, T, D, tp, dp = build_pair(smoke_arch, peak=peak)
    out = {}
    prompt = (np.arange(1, 9, dtype=np.int32) % 100).reshape(1, 8)
    for mode in ("serial", "parallel"):
        eng = SpecEngine(T, T, SpecConfig(bs=8, w=4, c=2, d=d, mode=mode, max_new=32), 512, 512)
        _, stats = eng.generate(tp, tp, prompt)
        out[mode] = stats.compression_ratio
    return out


def run():
    rows = []
    summary = {}
    for pair, (tgt, drf, smoke) in PAIRS.items():
        # allocations: serial co-located tp8; parallel disaggregated 6+2.
        t_t8, _ = infer_time_model(tgt, 8, 8, 512)
        t_d8, _ = infer_time_model(drf, 8, 8, 512)
        t_t6, _ = infer_time_model(tgt, 6, 8, 512)
        t_d2, _ = infer_time_model(drf, 2, 8, 512)
        # profile-chosen depth (paper §5.5): what parallel mode hides for free;
        # serial must PAY for the same depth to reach the same tree quality
        d = max(1, min(int(t_t6 / t_d2), 6))
        ratios = measured_ratios(smoke, d)

        def tps(mode, fused):
            k = KERNEL_SPEEDUP if fused else 1.0
            if mode == "parallel":
                t_round = max(t_t6 / k, d * t_d2 / k) + SYNC
                return ratios["parallel"] / t_round
            return ratios["serial"] / (t_t8 / k + d * t_d8 / k + SYNC)

        cfgs = {
            "swiftspec": tps("parallel", True),
            "only-parallel-tree": tps("parallel", False),
            "only-kernel-opt": tps("serial", True),
            "swiftspec-base": tps("serial", False),
        }
        summary[pair] = cfgs
        for name, v in cfgs.items():
            rows.append([pair, name, round(ratios["serial"], 2), round(ratios["parallel"], 2),
                         round(v, 1)])
        print(f"  {pair:22s} " + "  ".join(f"{n}={v:6.1f}" for n, v in cfgs.items()))

    path = write_csv("fig7_e2e.csv",
                     ["pair", "config", "compression_serial", "compression_parallel", "tokens_per_s"],
                     rows)
    speedups = [c["swiftspec"] / c["swiftspec-base"] for c in summary.values()]
    par_gain = [c["swiftspec"] / c["only-kernel-opt"] for c in summary.values()]
    kern_gain = [c["swiftspec"] / c["only-parallel-tree"] for c in summary.values()]
    print(f"  mean speedup vs swiftspec-base: {np.mean(speedups):.2f}x (paper: 1.75x)")
    print(f"  parallel-tree contribution:     {np.mean(par_gain):.2f}x (paper: 1.43x)")
    print(f"  kernel contribution:            {np.mean(kern_gain):.2f}x (paper: 1.16x)")
    # TPU note (DESIGN.md §3): drafting is relatively cheaper here than on
    # H800 (one fused XLA program vs per-kernel launches), so the paper's GPU
    # speedup is an upper bound; we assert the adapted win remains material.
    assert np.mean(speedups) > 1.25, np.mean(speedups)
    assert np.mean(par_gain) > 1.05, np.mean(par_gain)
    return path


if __name__ == "__main__":
    run()
