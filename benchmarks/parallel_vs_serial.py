"""Paper Table 6 — parallel vs serial tree generation: compression ratio per
dataset, draft/target step times, end-to-end decoding speed.

Regime: MEASURED dynamics + DERIVED schedule.  Compression ratios and round
counts are measured with the real engine on six synthetic "datasets" (Markov
streams of varying peakedness standing in for ALP/GSM/HE/MT/QA/SUM — no
public datasets offline); the decoding speed combines the measured ratios
with roofline step times for the paper's Qwen2-72B/1.5B pair under the
paper's split (serial: both tp8; parallel: target tp6 + draft tp2).

Claims reproduced: parallel compression ≈ 0.9x serial (the async tree loses
a little), end-to-end tokens/s gains ~1.3-1.5x from overlap."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import SpecConfig, SpecEngine

from benchmarks.common import build_pair, infer_time_model, write_csv

# six synthetic dataset analogues: (name, lm_head peaking) — peakier logits
# model more predictable text (code/math vs open QA)
DATASETS = [("ALP", 3.0), ("GSM", 5.0), ("HE", 6.0), ("MT", 3.5), ("QA", 2.5), ("SUM", 4.0)]

# paper pair: Qwen2-72B target + Qwen2-1.5B draft (public shapes)
QWEN72 = ModelConfig(name="qwen2-72b", n_layers=80, d_model=8192, n_heads=64,
                     n_kv_heads=8, d_ff=29568, vocab_size=152064, qkv_bias=True)
QWEN15 = ModelConfig(name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
                     n_kv_heads=2, d_ff=8960, vocab_size=151936, qkv_bias=True)


def measure_ratios(mode: str):
    out = {}
    for name, peak in DATASETS:
        cfgT, cfgD, T, D, tp, dp = build_pair(peak=peak)
        eng = SpecEngine(T, T, SpecConfig(bs=8, w=4, c=2, d=2, mode=mode, max_new=32),
                         512, 512)
        prompt = (np.arange(1, 9, dtype=np.int32) % 100).reshape(1, 8)
        _, stats = eng.generate(tp, tp, prompt)
        out[name] = stats.compression_ratio
    return out


def run():
    # measured engine dynamics
    r_serial = measure_ratios("serial")
    r_par = measure_ratios("parallel")

    # derived step times under the paper's allocations
    t_target_par, _ = infer_time_model(QWEN72, tp=6, bs=8, context=512)
    t_draft_par, _ = infer_time_model(QWEN15, tp=2, bs=8, context=512)
    t_target_ser, _ = infer_time_model(QWEN72, tp=8, bs=8, context=512)
    t_draft_ser, _ = infer_time_model(QWEN15, tp=8, bs=8, context=512)
    d = max(1, int(t_target_par / t_draft_par))  # paper §3.1 depth rule
    sync = 20e-6

    rows = []
    speeds = {}
    for name, _ in DATASETS:
        # serial round: target + d draft expansions, sequential
        t_round_ser = t_target_ser + d * t_draft_ser + sync
        # parallel round: drafting hides under verification
        t_round_par = max(t_target_par, d * t_draft_par) + sync
        tps_ser = r_serial[name] / t_round_ser
        tps_par = r_par[name] / t_round_par
        speeds[name] = (tps_ser, tps_par)
        rows.append([name, round(r_serial[name], 3), round(r_par[name], 3),
                     round(t_round_ser * 1e3, 3), round(t_round_par * 1e3, 3),
                     round(tps_ser, 1), round(tps_par, 1),
                     round(tps_par / tps_ser, 3)])

    path = write_csv(
        "table6_parallel_vs_serial.csv",
        ["dataset", "compression_serial", "compression_parallel",
         "round_ms_serial", "round_ms_parallel", "tok_s_serial", "tok_s_parallel", "speedup"],
        rows,
    )
    ratio_drop = np.mean([r_par[n] / r_serial[n] for n, _ in DATASETS])
    speedup = np.mean([p / s for s, p in speeds.values()])
    print(f"  d={d}; t_target(tp6)={t_target_par*1e3:.2f}ms t_draft(tp2)={t_draft_par*1e3:.2f}ms")
    print(f"  compression parallel/serial = {ratio_drop:.2f} (paper: ~0.91)")
    print(f"  mean e2e speedup parallel vs serial = {speedup:.2f}x (paper: 1.37x for Qwen2); {path}")
    assert 0.6 <= ratio_drop <= 1.05, ratio_drop
    assert speedup > 1.1, speedup
    return path


if __name__ == "__main__":
    run()
