"""Paper Figure 2 — compression ratio vs target batch size (bs) and draft
batch size (w).

Regime: MEASURED.  Real SpecEngine on CPU smoke models (draft = narrowed
target trained on nothing — acceptance comes from shared-structure logit
agreement, with peaked heads).  The claims to reproduce: compression grows
with bs but saturates (left plot), and stops improving once w exceeds ~8
(right plot)."""

from __future__ import annotations

import numpy as np

from repro.core.engine import SpecConfig, SpecEngine

from benchmarks.common import build_pair, write_csv


def _ratio(T, D, tp, dp, bs, w, rounds=3, d=2):
    eng = SpecEngine(T, D, SpecConfig(bs=bs, w=w, c=2, d=d, n_cap=max(64, 4 * bs),
                                      mode="serial", max_new=32), 512, 512)
    prompt = (np.arange(1, 9, dtype=np.int32) % 100).reshape(1, 8)
    _, stats = eng.generate(tp, dp, prompt)
    return stats.compression_ratio


def run():
    cfgT, cfgD, T, D, tp, dp = build_pair()
    rows = []
    # left plot: sweep target bs at fixed draft w
    ratios_bs = {}
    for bs in (2, 4, 8, 16):
        r = _ratio(T, T, tp, tp, bs=bs, w=8)
        ratios_bs[bs] = r
        rows.append(["target_bs_sweep", bs, 8, round(r, 3)])
    # right plot: sweep draft w at fixed target bs
    ratios_w = {}
    for w in (1, 2, 4, 8):
        r = _ratio(T, T, tp, tp, bs=8, w=w)
        ratios_w[w] = r
        rows.append(["draft_w_sweep", 8, w, round(r, 3)])
    path = write_csv("fig2_compression.csv", ["sweep", "bs", "w", "compression"], rows)
    print("  bs sweep (w=8):", {k: round(v, 2) for k, v in ratios_bs.items()})
    print("  w sweep (bs=8):", {k: round(v, 2) for k, v in ratios_w.items()})
    # paper shape: growth then saturation
    assert ratios_bs[8] >= ratios_bs[2] - 0.05, ratios_bs
    gain_tail = ratios_bs[16] - ratios_bs[8]
    gain_head = ratios_bs[8] - ratios_bs[2]
    print(f"  -> bs gain 2->8: {gain_head:+.2f}, 8->16: {gain_tail:+.2f} (saturating); {path}")
    return path


if __name__ == "__main__":
    run()
