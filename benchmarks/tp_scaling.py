"""Paper Table 1 — time per inference vs TP degree, llama family, bs=8.

Regime: DERIVED (roofline model, TPU v5e constants, int4 weights).  The
paper's observation to reproduce: small draft models stop benefiting from
more chips early (collective latency + dispatch floors dominate), while the
70B target keeps improving — the asymmetry motivating disaggregation."""

from __future__ import annotations

from repro.configs import get_config

from benchmarks.common import infer_time_model, write_csv

MODELS = ["llama3-1b", "llama3-3b", "llama3-8b", "llama3-70b"]
TPS = [1, 2, 4, 8]


def run():
    rows = []
    for name in MODELS:
        cfg = get_config(name)
        times = []
        for tp in TPS:
            t, parts = infer_time_model(cfg, tp, bs=8, context=512)
            times.append(t * 1e3)
            rows.append([name, tp, round(t * 1e3, 3),
                         round(parts["t_mem"] * 1e3, 3), round(parts["t_compute"] * 1e3, 4),
                         round(parts["t_coll"] * 1e3, 4), round(parts["t_disp"] * 1e3, 4)])
        # the paper's qualitative claims
        speedup_small = times[0] / times[-1]
        print(f"  {name:12s} " + "  ".join(f"tp{tp}={t:7.3f}ms" for tp, t in zip(TPS, times))
              + f"   tp1/tp8={speedup_small:.2f}x")
    path = write_csv("table1_tp_scaling.csv",
                     ["model", "tp", "ms_per_inference", "t_mem_ms", "t_comp_ms", "t_coll_ms", "t_disp_ms"],
                     rows)

    # the paper's shape (Table 1): the small draft saturates — tp8 is no
    # better than tp2 — while the 70B target keeps scaling well past tp2
    cfg_small, cfg_big = get_config("llama3-1b"), get_config("llama3-70b")
    t1 = {tp: infer_time_model(cfg_small, tp, 8, 512)[0] for tp in TPS}
    t70 = {tp: infer_time_model(cfg_big, tp, 8, 512)[0] for tp in TPS}
    assert t1[8] > 0.9 * t1[2], t1  # draft: no gain (or regression) beyond tp2
    assert t70[2] / t70[8] > 1.5, t70  # target: still scaling 2->8
    print(f"  -> draft saturates (1B tp2={t1[2]*1e3:.2f}ms vs tp8={t1[8]*1e3:.2f}ms); "
          f"target scales (70B tp2={t70[2]*1e3:.1f}ms -> tp8={t70[8]*1e3:.1f}ms); {path}")
    return path


if __name__ == "__main__":
    run()
