"""Render the §Dry-run / §Roofline markdown tables from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir benchmarks/results/dryrun]
  PYTHONPATH=src python -m benchmarks.roofline_report --compare benchmarks/results/dryrun_baseline
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath, mesh):
    out = {}
    for f in sorted(glob.glob(os.path.join(dirpath, mesh, "*.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt(x):
    return f"{x:.2e}"


def table(recs, title):
    lines = [f"### {title}", "",
             "| arch | shape | status | t_compute (s) | t_memory (s) | t_collective (s) "
             "| bottleneck | useful frac | roofline frac | mem/dev (GiB) | compile (s) |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | skip — sub-quadratic-only shape | | | | | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | FAIL | | | | | | | | |")
            continue
        rf = r["roofline"]
        gib = r["memory"]["peak_bytes_per_device"] / 2**30
        lines.append(
            f"| {arch} | {shape} | ok | {fmt(rf['t_compute_s'])} | {fmt(rf['t_memory_s'])} | "
            f"{fmt(rf['t_collective_s'])} | {rf['bottleneck']} | {rf['useful_fraction']:.3f} | "
            f"{rf['roofline_fraction']:.4f} | {gib:.2f} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def compare_table(new, old, cells):
    lines = ["| cell | term | baseline | optimized | delta |", "|---|---|---|---|---|"]
    for arch, shape in cells:
        a, b = old.get((arch, shape)), new.get((arch, shape))
        if not a or not b or a["status"] != "ok" or b["status"] != "ok":
            continue
        for term in ("t_compute_s", "t_memory_s", "t_collective_s"):
            ov, nv = a["roofline"][term], b["roofline"][term]
            d = (ov / nv) if nv else float("inf")
            lines.append(f"| {arch} × {shape} | {term} | {fmt(ov)} | {fmt(nv)} | {d:.2f}x |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--compare", default="")
    ap.add_argument("--cells", default="")
    args = ap.parse_args()

    for mesh, name in (("pod1", "single-pod 16×16 (256 chips)"),
                       ("pod2", "multi-pod 2×16×16 (512 chips)")):
        recs = load(args.dir, mesh)
        if recs:
            print(table(recs, f"{name}"))
            print()
    if args.compare:
        old = load(args.compare, "pod1")
        new = load(args.dir, "pod1")
        cells = [tuple(c.split(":")) for c in args.cells.split(",")] if args.cells else \
            [("deepseek-coder-33b", "decode_32k"), ("rwkv6-7b", "train_4k"),
             ("llama-3.2-vision-90b", "train_4k")]
        print("### baseline vs optimized (hillclimbed cells)\n")
        print(compare_table(new, old, cells))


if __name__ == "__main__":
    main()
