"""Benchmark harness: one entry per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table1 fig2

CSV outputs land in benchmarks/results/.  Regimes (measured vs derived) are
documented per module; the dry-run roofline table (EXPERIMENTS.md §Roofline)
is produced separately by repro.launch.dryrun.
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    ablation,
    allocation,
    compression,
    e2e,
    kernel_micro,
    parallel_vs_serial,
    serving,
    tp_scaling,
)

BENCHES = {
    "table1": ("Paper Table 1  — TP scaling per model size", tp_scaling.run),
    "fig2": ("Paper Figure 2 — compression vs bs / w", compression.run),
    "table6": ("Paper Table 6  — parallel vs serial tree generation", parallel_vs_serial.run),
    "fig7": ("Paper Figure 7 — end-to-end decoding speed", e2e.run),
    "fig8": ("Paper Figure 8 — ablation (parallel x kernels)", ablation.run),
    "table7": ("Paper Tables 3/7 — kernel micro-benchmarks", kernel_micro.run),
    "fig9": ("Paper Figure 9 — draft/target allocation sweep", allocation.run),
    "serving": ("Serving — replicas x offered-load sweep (sharded runtime)", serving.run),
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    failures = []
    for name in names:
        title, fn = BENCHES[name]
        print(f"\n=== {name}: {title} ===", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"  [{name} done in {time.time()-t0:.1f}s]")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}")
        raise SystemExit(1)
    print("\nall benchmarks ok")


if __name__ == "__main__":
    main()
