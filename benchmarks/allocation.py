"""Paper Figure 9 — decoding speed vs draft/target GPU allocation.

Regime: MEASURED dynamics + DERIVED schedule.  For each (target tp = x,
draft tp = 8-x) split, the round time comes from the roofline model and the
compression ratio from the measured engine (deeper trees when the draft is
faster, via the paper's d = t_target/t_draft rule).

Claim reproduced: big-target pairs (deepseek-coder-33b, qwen2-72b) prefer
6+2; pairs with a relatively stronger draft prefer 4+4."""

from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import SpecConfig, SpecEngine

from benchmarks.common import build_pair, infer_time_model, write_csv

PAIRS = {
    "dscoder-33b/1.3b": (
        ModelConfig(name="t", n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
                    d_ff=19200, vocab_size=32256),
        ModelConfig(name="d", n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
                    d_ff=5504, vocab_size=32256),
    ),
    "qwen2-72b/1.5b": (
        ModelConfig(name="t", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                    d_ff=29568, vocab_size=152064),
        ModelConfig(name="d", n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                    d_ff=8960, vocab_size=151936),
    ),
    "r1-llama-70b/8b": (  # strong 8B draft: more draft compute pays off
        ModelConfig(name="t", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                    d_ff=28672, vocab_size=128256),
        ModelConfig(name="d", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
                    d_ff=14336, vocab_size=128256),
    ),
}


def ratio_at_depth(T, tp, d):
    eng = SpecEngine(T, T, SpecConfig(bs=8, w=4, c=2, d=max(1, min(d, 6)), mode="parallel",
                                      max_new=32), 512, 512)
    prompt = (np.arange(1, 9, dtype=np.int32) % 100).reshape(1, 8)
    _, st = eng.generate(tp, tp, prompt)
    return st.compression_ratio


def run():
    _, _, T, D, tpv, dpv = build_pair()
    rows = []
    best = {}
    # measured compression as a function of achievable tree depth d
    ratio_cache = {d: ratio_at_depth(T, tpv, d) for d in range(1, 7)}
    for pair, (tgt, drf) in PAIRS.items():
        scores = {}
        for x in (2, 4, 6):  # even target TP (paper §5.5)
            t_t, _ = infer_time_model(tgt, x, 8, 512)
            t_d, _ = infer_time_model(drf, 8 - x, 8, 512)
            d = max(1, min(int(t_t / t_d), 6))
            ratio = ratio_cache[d]
            tps = ratio / (max(t_t, d * t_d) + 20e-6)
            scores[x] = tps
            rows.append([pair, x, 8 - x, round(t_t * 1e3, 2), round(t_d * 1e3, 2), d,
                         round(ratio, 2), round(tps, 1)])
        best[pair] = max(scores, key=scores.get)
        print(f"  {pair:20s} " + "  ".join(f"{x}+{8-x}={v:6.1f}t/s" for x, v in scores.items())
              + f"  -> best target tp = {best[pair]}")
    path = write_csv("fig9_allocation.csv",
                     ["pair", "target_tp", "draft_tp", "t_target_ms", "t_draft_ms",
                      "depth_d", "compression", "tokens_per_s"], rows)
    assert best["dscoder-33b/1.3b"] == 6 and best["qwen2-72b/1.5b"] == 6, best
    print(f"  -> 33B/72B targets prefer 6+2 (paper Fig. 9); {path}")
    return path


if __name__ == "__main__":
    run()
