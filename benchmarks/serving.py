"""Serving benchmark — replicas x offered-load sweep over the sharded
continuous-batching runtime (regime: measured engine dynamics on CPU smoke
models; absolute wall tok/s is container-bound, the *shape* is the result:
TTFT growth and occupancy saturation as offered load approaches one
replica's capacity, and the sustained-throughput headroom a second replica
adds at saturating load).

For each (replica count, offered Poisson rate) cell, a seeded trace is
replayed on a VirtualClock (deterministic admission schedule, immune to CPU
compile noise) while wall-clock throughput is measured separately.  One
global round of the sharded loop advances the virtual clock once while
every busy replica steps — replica rounds run concurrently on disjoint
device groups in a real deployment — so ``sustained_tok_s`` (tokens per
virtual second over the serving window) is the scaling signal: at a rate
that saturates one replica, two replicas drain the same trace in fewer
global rounds.  CSV: replicas, rate, finished, sustained tok/s (virtual),
wall tok/s, TTFT p50/p99 (virtual s), per-replica mean occupancy, queue
shed.

The SLO sweep (``slo_sweep``) replays deadlined traffic — offered load x
deadline tightness — under the fixed global draft depth and under the
per-slot adaptive scheduler (docs/scheduling.md), on a virtual clock that
charges per draft expansion, and asserts the adaptive policy beats fixed
depth at saturating load on attainment or p99 TTFT.  The attainment curves
land in ``serving.json`` (``slo_cells`` / ``slo_summary``).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import write_csv, write_json
from repro.configs.base import ModelConfig
from repro.core.engine import SpecConfig, SpecEngine
from repro.data import make_request_trace
from repro.models.api import make_model
from repro.obs import MetricsRegistry, Tracer, breakdown_report, phase_breakdown
from repro.serving import (
    Request,
    RequestQueue,
    SchedulerConfig,
    ShardedServingRuntime,
    VirtualClock,
)

REPLICAS = (1, 2)
RATES = (0.2, 1.0, 4.0)  # offered load, requests per virtual second
N_REQUESTS = 10
N_SLOTS = 2  # per replica
MAX_NEW = 16

# ---- SLO sweep (docs/scheduling.md): offered load x deadline tightness,
# fixed global depth vs per-slot adaptive depth.  The virtual clock charges
# ``expand_dt`` per draft expansion the round actually ran, so shallower
# adaptive rounds are measurably cheaper — the cost model under which the
# scheduler has to earn its attainment/p99 win (byte-identity of outputs is
# asserted separately in tests/test_scheduler.py; here only timing differs).
SLO_RATES = (1.0, 4.0)  # req / virtual s; max saturates one 2-slot replica
SLO_DEADLINES = (3.0, 10.0)  # finish deadline, virtual s after arrival
SLO_FIXED_D = 4  # the global depth the adaptive policy competes against
SLO_ROUND_DT = 0.1
SLO_EXPAND_DT = 0.05  # a depth-4 round costs 0.3 vs 0.15 at depth 1


def _build():
    cfgT = ModelConfig(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=128)
    cfgD = ModelConfig(name="d", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=64, vocab_size=128)
    T, D = make_model(cfgT), make_model(cfgD)
    tp, dp = T.init(jax.random.PRNGKey(0)), D.init(jax.random.PRNGKey(1))
    tp["lm_head"].value = tp["lm_head"].value * 4.0
    dp["lm_head"].value = dp["lm_head"].value * 4.0
    eng = SpecEngine(T, D, SpecConfig(bs=8, w=4, c=2, d=2, max_new=MAX_NEW),
                     S_max_t=256, S_max_d=256)
    return eng, tp, dp, cfgT


def _warmup(eng, tp, dp, cfgT) -> None:
    """Pay every one-time XLA compile outside the timed sweeps so the first
    cell's wall tok/s column is comparable to the rest.  Each distinct
    prompt length is one prefill compile, so cover every 4-token bucket the
    sweep's prompt_len=(8, 16) range can draw."""
    rng = np.random.default_rng(3)
    rt = ShardedServingRuntime([eng], tp, dp, n_slots=N_SLOTS,
                               clock=VirtualClock(round_dt=0.1))
    for i, P in enumerate(range(8, 17, 4)):
        prompt = rng.integers(0, cfgT.vocab_size, size=(P,), dtype=np.int32)
        rt.submit(Request(rid=i, prompt=prompt, arrival_s=0.0, max_new=4))
    rt.run()


def slo_sweep(eng, tp, dp, cfgT):
    """offered load x deadline tightness x {fixed d, adaptive} -> SLO curves.

    Returns (cells, summary): per-cell attainment / slack / TTFT rows plus
    the saturating-load comparison the trajectory tracks."""
    import dataclasses

    deep = SpecEngine(eng.target, eng.draft,
                      dataclasses.replace(eng.cfg, d=SLO_FIXED_D),
                      S_max_t=256, S_max_d=256)
    cells = []
    att = {}  # (rate, deadline, policy) -> (attainment, ttft_p99)
    for rate in SLO_RATES:
        for ddl in SLO_DEADLINES:
            for policy, sched in (("fixed", None), ("adaptive", SchedulerConfig())):
                trace = make_request_trace(cfgT.vocab_size, N_REQUESTS,
                                           rate_rps=rate, prompt_len=(8, 16),
                                           max_new=MAX_NEW, seed=7)
                rt = ShardedServingRuntime(
                    [deep], tp, dp, n_slots=N_SLOTS,
                    queue=RequestQueue(cap=2 * N_REQUESTS),
                    clock=VirtualClock(round_dt=SLO_ROUND_DT,
                                       expand_dt=SLO_EXPAND_DT),
                    scheduler=sched,
                )
                rt.submit_trace(
                    Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s,
                            max_new=r.max_new, deadline_s=r.arrival_s + ddl)
                    for r in trace)
                rt.run()
                s = rt.summary()
                att[(rate, ddl, policy)] = (s["slo_attainment"], s["ttft_p99_s"])
                cells.append({
                    "offered_rate_rps": rate, "deadline_s": ddl,
                    "policy": policy, "n_deadlined": s["n_deadlined"],
                    "slo_attainment": round(s["slo_attainment"], 3),
                    "slack_p50_s": round(s["slack_p50_s"], 3),
                    "slack_p10_s": round(s["slack_p10_s"], 3),
                    "ttft_p99_s": round(s["ttft_p99_s"], 3),
                    "sustained_tok_s": round(s["throughput_tok_s"], 2),
                })
                print(f"  slo: rate={rate:4.1f}/s deadline={ddl:4.1f}s "
                      f"{policy:8s} attain={s['slo_attainment']:.2f} "
                      f"slack p50={s['slack_p50_s']:+.2f} "
                      f"ttft p99={s['ttft_p99_s']:.3f}")
    sat, tight = max(SLO_RATES), min(SLO_DEADLINES)
    f_att, f_p99 = att[(sat, tight, "fixed")]
    a_att, a_p99 = att[(sat, tight, "adaptive")]
    summary = {
        "saturating_rate_rps": sat, "tight_deadline_s": tight,
        "fixed_attainment": f_att, "adaptive_attainment": a_att,
        "fixed_ttft_p99_s": f_p99, "adaptive_ttft_p99_s": a_p99,
    }
    return cells, summary


def run() -> None:
    eng, tp, dp, cfgT = _build()
    _warmup(eng, tp, dp, cfgT)
    rows = []
    peak_occ = []
    sustained = {}  # (replicas, rate) -> virtual tok/s
    # one tracer across the whole sweep: the aggregate draft/verify/absorb
    # round decomposition (wall time, jits warm) is the perf-trajectory signal
    tracer = Tracer()
    metrics = MetricsRegistry()
    for n_rep in REPLICAS:
        for rate in RATES:
            trace = make_request_trace(cfgT.vocab_size, N_REQUESTS, rate_rps=rate,
                                       prompt_len=(8, 16), max_new=MAX_NEW, seed=7)
            # the same engine object serves every replica on this one-device
            # container: states are per-replica, the jit cache is shared
            rt = ShardedServingRuntime(
                [eng] * n_rep, tp, dp, n_slots=N_SLOTS,
                queue=RequestQueue(cap=2 * N_REQUESTS),
                clock=VirtualClock(round_dt=0.1),  # 10 global rounds / virtual s
                tracer=tracer, metrics=metrics,
            )
            rt.submit_trace(Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s,
                                    max_new=r.max_new) for r in trace)
            t0 = time.perf_counter()
            results = rt.run()
            wall = time.perf_counter() - t0
            s = rt.summary()
            total = sum(len(v) for v in results.values())
            sustained[(n_rep, rate)] = s["throughput_tok_s"]
            occ = "|".join(f"{o:.2f}" for o in s["per_replica_occupancy"])
            rows.append([n_rep, rate, s["n_finished"],
                         round(s["throughput_tok_s"], 2), round(total / wall, 2),
                         round(s["ttft_p50_s"], 3), round(s["ttft_p99_s"], 3),
                         occ, rt.queue.rejected])
            print(f"  replicas={n_rep} rate={rate:5.1f}/s finished={s['n_finished']} "
                  f"sustained={s['throughput_tok_s']:6.1f} tok/vs wall={total/wall:7.1f} tok/s "
                  f"ttft p50={s['ttft_p50_s']:.3f} p99={s['ttft_p99_s']:.3f} occ={occ}")
            peak_occ.extend(max(st.occupancy_samples) for st in rt.stats
                            if st.occupancy_samples)
    path = write_csv("serving.csv",
                     ["replicas", "offered_rate_rps", "finished", "sustained_tok_s",
                      "wall_tok_s", "ttft_p50_s", "ttft_p99_s",
                      "occupancy_per_replica", "shed"],
                     rows)
    print(f"  -> {path}")
    # async-disaggregation cell: replay the saturating-rate trace with
    # async rounds on, on its own tracer — the overlap metrics are the
    # evidence draft work left the critical path (tests assert the outputs
    # are byte-identical, so only the schedule differs)
    import dataclasses

    a_eng = SpecEngine(eng.target, eng.draft,
                       dataclasses.replace(eng.cfg, async_rounds=True),
                       S_max_t=256, S_max_d=256)
    a_tracer = Tracer()
    a_trace = make_request_trace(cfgT.vocab_size, N_REQUESTS, rate_rps=max(RATES),
                                 prompt_len=(8, 16), max_new=MAX_NEW, seed=7)
    a_rt = ShardedServingRuntime([a_eng], tp, dp, n_slots=N_SLOTS,
                                 clock=VirtualClock(round_dt=0.1), tracer=a_tracer)
    a_rt.submit_trace(Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s,
                              max_new=r.max_new) for r in a_trace)
    a_rt.run()
    a_bd = phase_breakdown(a_tracer)

    # SLO sweep: deadline attainment under fixed vs adaptive draft depth
    slo_cells, slo_summary = slo_sweep(eng, tp, dp, cfgT)

    # BENCH JSON: the sweep cells plus the measured round-time decomposition
    # (draft vs verify fraction — the paper's imbalance) for the trajectory.
    # accept_depth_mean merges the per-replica histogram family (replicas may
    # run different draft depths, so edges are unioned, not summed by index).
    bd = phase_breakdown(tracer)
    from repro.obs import merge_histograms

    accept = merge_histograms(
        [h for _, h in metrics.histogram_family("serving_accept_depth")])
    jpath = write_json("serving.json", {
        "cells": [
            {"replicas": r[0], "offered_rate_rps": r[1], "finished": r[2],
             "sustained_tok_s": r[3], "wall_tok_s": r[4],
             "ttft_p50_s": r[5], "ttft_p99_s": r[6],
             "occupancy_per_replica": r[7], "shed": r[8]}
            for r in rows
        ],
        "phase_breakdown": bd,
        "accept_depth_mean": accept.mean,
        "async_phase_breakdown": a_bd,
        "async_overlap_draft_verify_s": a_bd["overlap_draft_verify_s"],
        "async_draft_serialized_frac": a_bd["draft_serialized_frac"],
        "lockstep_draft_serialized_frac": bd["draft_serialized_frac"],
        "slo_cells": slo_cells,
        "slo_summary": slo_summary,
    })
    print(breakdown_report(bd))
    print(f"  async: draft overlapped verify {a_bd['overlap_draft_verify_s']*1e3:.1f} ms, "
          f"serialized draft {a_bd['draft_serialized_frac']:.1%} of round "
          f"(lockstep {bd['draft_serialized_frac']:.1%})")
    print(f"  -> {jpath}")
    # sanity AFTER the CSV lands, so a violation can't discard data
    assert all(p <= N_SLOTS for p in peak_occ), peak_occ
    sat = max(RATES)  # saturating load: the sharding payoff must show
    assert sustained[(2, sat)] > sustained[(1, sat)], (
        f"2 replicas did not out-serve 1 at rate {sat}: {sustained}")
    # adaptive depth must beat the fixed global d at saturating load on
    # attainment or p99 TTFT (shallower rounds are cheaper under the
    # expand_dt cost model; outputs are identical, only timing moves)
    ss = slo_summary
    assert (ss["adaptive_attainment"] > ss["fixed_attainment"]
            or ss["adaptive_ttft_p99_s"] < ss["fixed_ttft_p99_s"]), (
        f"adaptive depth did not beat fixed d={SLO_FIXED_D} at saturating "
        f"load: {ss}")


if __name__ == "__main__":
    run()
