"""Serving benchmark — offered-throughput sweep over the continuous-batching
runtime (regime: measured engine dynamics on CPU smoke models; absolute
tok/s is container-bound, the *shape* — TTFT growth and occupancy saturation
as offered load approaches capacity — is the result).

For each offered Poisson rate, a seeded trace is replayed on a VirtualClock
(deterministic admission schedule, immune to CPU compile noise) while
wall-clock throughput is measured separately.  CSV: rate, finished, tok/s,
TTFT p50/p99 (virtual s), mean occupancy, mean acceptance, queue shed.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import write_csv
from repro.configs.base import ModelConfig
from repro.core.engine import SpecConfig, SpecEngine
from repro.data import make_request_trace
from repro.models.api import make_model
from repro.serving import ContinuousBatchingRuntime, Request, RequestQueue, VirtualClock

RATES = (0.2, 1.0, 4.0)  # offered load, requests per virtual second
N_REQUESTS = 8
N_SLOTS = 2
MAX_NEW = 16


def _build():
    cfgT = ModelConfig(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=128)
    cfgD = ModelConfig(name="d", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=64, vocab_size=128)
    T, D = make_model(cfgT), make_model(cfgD)
    tp, dp = T.init(jax.random.PRNGKey(0)), D.init(jax.random.PRNGKey(1))
    tp["lm_head"].value = tp["lm_head"].value * 4.0
    dp["lm_head"].value = dp["lm_head"].value * 4.0
    eng = SpecEngine(T, D, SpecConfig(bs=8, w=4, c=2, d=2, max_new=MAX_NEW),
                     S_max_t=256, S_max_d=256)
    return eng, tp, dp, cfgT


def _warmup(eng, tp, dp, cfgT) -> None:
    """Pay every one-time XLA compile outside the timed sweeps so the first
    offered rate's tok/s column is comparable to the rest.  Each distinct
    prompt length is one prefill compile, so cover every 4-token bucket the
    sweep's prompt_len=(8, 16) range can draw."""
    rng = np.random.default_rng(3)
    rt = ContinuousBatchingRuntime(eng, tp, dp, n_slots=N_SLOTS,
                                   clock=VirtualClock(round_dt=0.1))
    for i, P in enumerate(range(8, 17, 4)):
        prompt = rng.integers(0, cfgT.vocab_size, size=(P,), dtype=np.int32)
        rt.submit(Request(rid=i, prompt=prompt, arrival_s=0.0, max_new=4))
    rt.run()


def run() -> None:
    eng, tp, dp, cfgT = _build()
    _warmup(eng, tp, dp, cfgT)
    rows = []
    peak_occ = []
    for rate in RATES:
        trace = make_request_trace(cfgT.vocab_size, N_REQUESTS, rate_rps=rate,
                                   prompt_len=(8, 16), max_new=MAX_NEW, seed=7)
        rt = ContinuousBatchingRuntime(
            eng, tp, dp, n_slots=N_SLOTS,
            queue=RequestQueue(cap=2 * N_REQUESTS),
            clock=VirtualClock(round_dt=0.1),  # 10 rounds / virtual second
        )
        rt.submit_trace(Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s,
                                max_new=r.max_new) for r in trace)
        t0 = time.perf_counter()
        results = rt.run()
        wall = time.perf_counter() - t0
        s = rt.stats.summary()
        total = sum(len(v) for v in results.values())
        rows.append([rate, s["n_finished"], round(total / wall, 2),
                     round(s["ttft_p50_s"], 3), round(s["ttft_p99_s"], 3),
                     round(s["mean_occupancy"], 3), round(s["mean_acceptance"], 3),
                     rt.queue.rejected])
        print(f"  rate={rate:5.1f}/s finished={s['n_finished']} tok/s={total/wall:7.1f} "
              f"ttft p50={s['ttft_p50_s']:.3f} p99={s['ttft_p99_s']:.3f} "
              f"occ={s['mean_occupancy']:.2f} acc={s['mean_acceptance']:.2f}")
        peak_occ.append(max(rt.stats.occupancy_samples))
    path = write_csv("serving.csv",
                     ["offered_rate_rps", "finished", "tok_per_s", "ttft_p50_s",
                      "ttft_p99_s", "mean_occupancy", "mean_acceptance", "shed"],
                     rows)
    print(f"  -> {path}")
    # saturation sanity AFTER the CSV lands, so a violation can't discard data
    assert all(p <= N_SLOTS for p in peak_occ), peak_occ


if __name__ == "__main__":
    run()
