"""Paper Figure 8 — ablation over the two optimization classes (parallel tree
generation × latency-optimized kernels), plus the WALL-CLOCK overlap ablation
measurable on this container: serial vs parallel engine mode with identical
models (single device, so the parallel win shows up as compression retention
while the schedule model shows the latency side).

Regime: MEASURED (engine) + the Figure-7 grid (benchmarks/e2e.py) for the
derived four-config comparison."""

from __future__ import annotations

import time

import numpy as np

from repro.core.engine import SpecConfig, SpecEngine

from benchmarks.common import build_pair, write_csv


def run():
    cfgT, cfgD, T, D, tp, dp = build_pair()
    prompt = (np.arange(1, 9, dtype=np.int32) % 100).reshape(1, 8)
    rows = []
    stats_by = {}
    for mode in ("serial", "parallel"):
        for bypass in (False, True):
            eng = SpecEngine(T, T, SpecConfig(bs=8, w=4, c=2, d=2, mode=mode,
                                              max_new=48, draft_bypass=bypass), 512, 512)
            t0 = time.perf_counter()
            out, st = eng.generate(tp, tp, prompt)
            dt = time.perf_counter() - t0
            key = f"{mode}{'+bypass' if bypass else ''}"
            stats_by[key] = st
            rows.append([key, len(out[0]), st.rounds, round(st.compression_ratio, 3),
                         st.draft_steps, round(dt, 2)])
            print(f"  {key:18s} rounds={st.rounds:3d} compression={st.compression_ratio:.2f} "
                  f"draft_steps={st.draft_steps}")

    path = write_csv("fig8_ablation.csv",
                     ["config", "tokens", "rounds", "compression", "draft_steps", "wall_s"], rows)
    # parallel keeps most of serial's compression (paper: 91%)
    keep = stats_by["parallel"].compression_ratio / stats_by["serial"].compression_ratio
    print(f"  parallel keeps {keep:.0%} of serial compression (paper: ~91%)")
    # bypass degrades compression toward 1 (the straggler fallback)
    assert stats_by["parallel+bypass"].compression_ratio <= stats_by["parallel"].compression_ratio + 1e-9
    return path


if __name__ == "__main__":
    run()
