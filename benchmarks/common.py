"""Shared benchmark plumbing: model builders, timing, CSV output.

Two measurement regimes on this CPU-only container (each benchmark states
which it uses):
  measured — wall-clock of the real engine/model on CPU smoke configs
             (engine dynamics: compression ratios, acceptance, schedules);
  derived  — analytic roofline model with TPU v5e constants fed by config
             shapes and dry-run artifacts (absolute per-op/per-inference
             times, where CPU wall-clock would be meaningless).
"""

from __future__ import annotations

import csv
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# TPU v5e-class chip model (assignment constants)
PEAK_FLOPS = 197e12  # bf16/int8-dequant MXU
HBM_BW = 819e9
LINK_BW = 50e9
AR_BASE = 3e-6  # software latency floor of one small all-reduce
ICI_HOP = 0.8e-6  # per-hop ICI latency (ring all-reduce: 2(tp-1) hops)
OP_OVERHEAD = 1.5e-6  # per fused-op dispatch floor at bs<=16 (latency regime)


def write_csv(name: str, header, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def write_json(name: str, payload: dict):
    """Structured BENCH JSON next to the CSVs (sections the perf trajectory
    tracks, e.g. serving.json's ``phase_breakdown``)."""
    import json

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def time_call(fn, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def build_pair(target_arch="qwen2.5-14b", draft_layers=2, seed=0, peak=4.0):
    """(target, draft) smoke models sharing a vocab; draft = narrow target."""
    import dataclasses

    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.api import make_model

    cfgT = get_config(target_arch, smoke=True)
    cfgD = dataclasses.replace(cfgT, name=cfgT.name + "-draft", n_layers=draft_layers,
                               d_model=max(32, cfgT.d_model // 2),
                               n_heads=max(2, cfgT.n_heads // 2) if cfgT.n_heads else 0,
                               n_kv_heads=max(1, cfgT.n_kv_heads // 2) if cfgT.n_kv_heads else 0,
                               d_ff=max(32, cfgT.d_ff // 2))
    T, D = make_model(cfgT), make_model(cfgD)
    tp = T.init(jax.random.PRNGKey(seed))
    dp = D.init(jax.random.PRNGKey(seed + 1))
    tp["lm_head"].value = tp["lm_head"].value * peak
    dp["lm_head"].value = dp["lm_head"].value * peak
    return cfgT, cfgD, T, D, tp, dp


# -----------------------------------------------------------------------------
# analytic roofline time model (derived regime)
# -----------------------------------------------------------------------------


def infer_time_model(cfg, tp: int, bs: int, context: int, *, weight_bytes: float = 0.5,
                     act_bytes: float = 2.0):
    """Roofline time for ONE forward of ``bs`` tokens at ``context`` length,
    model sharded TP-``tp``.  weight_bytes=0.5 -> int4 AWQ (paper's serving
    precision).  Returns (t_total, parts dict)."""
    n_active = cfg.active_param_count()
    d = cfg.d_model
    n_layers = cfg.n_layers
    kv_heads = max(cfg.n_kv_heads, 1) if cfg.n_heads else 0
    hd = cfg.head_dim or 0

    t_weights = n_active * weight_bytes / tp / HBM_BW
    kv_bytes = 2 * n_layers * context * kv_heads * hd * act_bytes if cfg.n_heads else 0
    t_kv = kv_bytes / tp / HBM_BW
    t_compute = 2.0 * n_active * bs / (tp * PEAK_FLOPS)
    t_attn = 4.0 * bs * context * (cfg.n_heads or 0) * hd * n_layers / (tp * PEAK_FLOPS)

    # two all-reduces per layer of a [bs, d] bf16 activation (latency-bound at
    # small bs: the paper's fused-LL regime); ring bytes + hop/software floors
    ar_bytes = bs * d * act_bytes
    t_coll = 0.0
    if tp > 1:
        t_one = AR_BASE + 2 * (tp - 1) * ICI_HOP + ar_bytes * (tp - 1) / tp / LINK_BW
        t_coll = 2 * n_layers * t_one
    # dispatch floor: ~7 fused ops per layer
    t_disp = 7 * n_layers * OP_OVERHEAD

    t_mem = t_weights + t_kv
    t = max(t_mem, t_compute + t_attn) + t_coll + t_disp
    return t, {
        "t_weights": t_weights, "t_kv": t_kv, "t_compute": t_compute + t_attn,
        "t_coll": t_coll, "t_disp": t_disp, "t_mem": t_mem,
    }
