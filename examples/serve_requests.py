"""Serve a stream of requests end to end (deliverable (b), serving kind):
profile pass -> engine -> batched request stream -> per-request stats.

  PYTHONPATH=src python examples/serve_requests.py [--requests 4]

This drives the same launch/serve.py production path used at scale; on this
CPU container both device groups share one device (correctness only)."""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--requests", "3", "--max-new", "32", "--mode", "parallel"])
