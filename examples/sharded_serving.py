"""Sharded serving demo: one queue, two engine replicas (tiny models, CPU).

  PYTHONPATH=src python examples/sharded_serving.py

Submits a seeded Poisson burst of 8 requests to a ShardedServingRuntime with
2 replicas x 2 slots.  Watch the routing: each popped request lands on the
least-loaded replica (FIFO tie-break), both replicas decode concurrently
(one global round = every busy replica steps once), and the fleet report
shows per-replica occupancy under one set of global TTFT/throughput numbers.
On this CPU host both replicas share the device (and the engine's jit
cache); on a real slice each replica owns a disjoint (target, draft) device
pair from ``make_serving_mesh(..., replicas=2)``.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import SpecConfig, SpecEngine
from repro.data import make_request_trace
from repro.models.api import make_model
from repro.serving import Request, ShardedServingRuntime, VirtualClock

cfgT = ModelConfig(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab_size=128)
cfgD = ModelConfig(name="d", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=128)
T, D = make_model(cfgT), make_model(cfgD)
tp, dp = T.init(jax.random.PRNGKey(0)), D.init(jax.random.PRNGKey(1))
tp["lm_head"].value = tp["lm_head"].value * 4.0  # peaked greedy chains
dp["lm_head"].value = dp["lm_head"].value * 4.0

engine = SpecEngine(T, D, SpecConfig(bs=8, w=4, c=2, d=2, max_new=24),
                    S_max_t=256, S_max_d=256)

trace = make_request_trace(cfgT.vocab_size, 8, rate_rps=2.0, prompt_len=(8, 16),
                           max_new=16, seed=42)

# the same engine object twice: states are per-replica, jit cache shared
# (on a multi-device slice, build one engine per disjoint mesh pair instead)
runtime = ShardedServingRuntime(
    [engine, engine], tp, dp, n_slots=2,
    clock=VirtualClock(round_dt=0.25),  # deterministic replay: 4 rounds/virtual s
)
runtime.submit_trace(
    Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s, max_new=r.max_new)
    for r in trace
)
results = runtime.run()

print(runtime.report())
print()

# sharding changed the schedule, never the tokens
session = engine.session(tp, dp)  # bound round API: params live on the session
for r in trace:
    solo, _ = session.generate(r.prompt.reshape(1, -1), max_new=r.max_new)
    assert results[r.rid] == solo[0]
used = sorted({runtime.replica_of(r.rid) for r in trace})
print(f"all {len(results)} outputs byte-identical to solo generate(); "
      f"replicas used: {used}")
