"""Quickstart: asynchronous disaggregated speculative decoding in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a small target/draft pair, decodes one prompt with the SwiftSpec
engine in parallel (async) mode, and verifies the output equals plain greedy
decoding — the system's correctness contract."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.engine import SpecConfig, SpecEngine
from repro.models.api import make_model

# 1. models: any two archs sharing a vocab work; here target = qwen smoke,
#    draft = the same weights (a stand-in for a distilled small model)
cfg = get_config("qwen2.5-14b", smoke=True)
model = make_model(cfg)
params = model.init(jax.random.PRNGKey(0))
params["lm_head"].value = params["lm_head"].value * 4.0  # peaked logits

# 2. engine: bs/w/c/d are the paper's knobs (§5.5)
engine = SpecEngine(
    target=model, draft=model,
    cfg=SpecConfig(bs=8, w=4, c=2, d=2, mode="parallel", max_new=32),
    S_max_t=256, S_max_d=256,
)

prompt = (np.arange(1, 9, dtype=np.int32) % cfg.vocab_size).reshape(1, 8)
session = engine.session(params, params)  # bound round API (params + state)
out, stats = session.generate(prompt)
print("speculative:", out[0])
print(f"rounds={stats.rounds} compression={stats.compression_ratio:.2f} "
      f"tokens/round={stats.tokens_per_round:.2f}")

# 3. the correctness contract: equality with target-only greedy decoding
lg, cache = jax.jit(lambda p, t: model.prefill(p, tokens=t, S_max=256))(params, jnp.asarray(prompt))
cur = jnp.argmax(lg[:, -1, :], -1)[:, None].astype(jnp.int32)
ref = [int(cur[0, 0])]
step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, 256))
for _ in range(31):
    lg, cache = step(params, cache, cur)
    cur = jnp.argmax(lg[:, -1, :], -1)[:, None].astype(jnp.int32)
    ref.append(int(cur[0, 0]))
assert out[0] == ref, "speculative decoding diverged from greedy!"
print("matches target-only greedy decoding — OK")
