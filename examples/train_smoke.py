"""End-to-end training driver example: ~100M-class model, a few hundred
steps, checkpoints + resume (deliverable (b), training kind).

  PYTHONPATH=src python examples/train_smoke.py [--steps 200]

Uses the same launch/train.py path the dry-run lowers at production scale
(scan-over-layers, AdamW with f32 masters, deterministic data)."""

import sys
import tempfile

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if not args:
        ckpt = tempfile.mkdtemp(prefix="repro-ckpt-")
        args = ["--arch", "qwen2.5-14b", "--steps", "200", "--batch", "8",
                "--seq", "128", "--lr", "3e-3", "--ckpt", ckpt, "--ckpt-every", "50"]
    main(args)
