"""Continuous-batching serving demo (tiny models, CPU-friendly).

  PYTHONPATH=src python examples/continuous_serving.py

Submits a seeded Poisson trace of 6 requests to a 2-slot
ContinuousBatchingRuntime and streams each request's tokens as they are
verified.  Watch the telemetry: requests are admitted while their neighbors
are mid-decode (overlapping round intervals), retiring slots are backfilled
from the queue, and each request gets its own TTFT / tok/s / acceptance row.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import SpecConfig, SpecEngine
from repro.data import make_request_trace
from repro.models.api import make_model
from repro.serving import ContinuousBatchingRuntime, Request, VirtualClock

cfgT = ModelConfig(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab_size=128)
cfgD = ModelConfig(name="d", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=128)
T, D = make_model(cfgT), make_model(cfgD)
tp, dp = T.init(jax.random.PRNGKey(0)), D.init(jax.random.PRNGKey(1))
tp["lm_head"].value = tp["lm_head"].value * 4.0  # peaked greedy chains
dp["lm_head"].value = dp["lm_head"].value * 4.0

engine = SpecEngine(T, D, SpecConfig(bs=8, w=4, c=2, d=2, max_new=24),
                    S_max_t=256, S_max_d=256)

trace = make_request_trace(cfgT.vocab_size, 6, rate_rps=1.0, prompt_len=(8, 16),
                           max_new=24, seed=42)


def stream(rid, tokens, done):
    tail = "  <done>" if done else ""
    print(f"  req {rid}: +{len(tokens)} tokens {tokens}{tail}")


runtime = ContinuousBatchingRuntime(
    engine, tp, dp, n_slots=2,
    clock=VirtualClock(round_dt=0.25),  # deterministic replay: 4 rounds/virtual s
    stream=stream,
)
runtime.submit_trace(
    Request(rid=r.rid, prompt=r.prompt, arrival_s=r.arrival_s, max_new=r.max_new)
    for r in trace
)
results = runtime.run()

print()
print(runtime.stats.report())

# the runtime's outputs are byte-identical to solo generate() runs
session = engine.session(tp, dp)  # bound round API: params live on the session
for r in trace:
    solo, _ = session.generate(r.prompt.reshape(1, -1), max_new=r.max_new)
    assert results[r.rid] == solo[0]
print(f"\nall {len(results)} outputs byte-identical to solo generate() — continuous "
      f"batching changed the schedule, not the tokens")
