"""The paper's core mechanism, exposed: parallel tree generation with
KV-consistency management, round by round (Figure 3 as a runnable trace).

  PYTHONPATH=src python examples/disaggregated_demo.py

Prints, per decoding round: tree size, the subgraph sent for verification,
accepted path, re-root compaction, and KV prefix growth — plus the chain-mode
equivalent on an SSM arch (DESIGN.md §6)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import tree as T
from repro.core.chain_engine import ChainConfig, ChainSpecEngine
from repro.models.api import make_model

cfg = get_config("qwen2.5-14b", smoke=True)
model = make_model(cfg)
params = model.init(jax.random.PRNGKey(0))
params["lm_head"].value = params["lm_head"].value * 4.0

prompt = (np.arange(1, 9, dtype=np.int32) % cfg.vocab_size).reshape(1, 8)
S_MAX, BS, W, C = 128, 6, 3, 2

print("=== tree-based rounds (paper Fig. 3) ===")
lg, cache = model.prefill(params, tokens=jnp.asarray(prompt), S_max=S_MAX)
tr = jax.tree.map(lambda x: x[None] if x.ndim else x, T.init_tree(32))
tr = jax.tree.map(lambda x: x, tr)
tr0 = T.init_tree(32)
tr0 = T.seed_root(tr0, int(prompt[0, -1]), prompt.shape[1], lg[0, -1, :], C)

tcache = cache
for rnd in range(3):
    # draft side: expand twice
    for _ in range(2):
        ids, valid = T.select_leaves(tr0, W)
        toks, rows, pos, mask, _ = T.leaf_inputs(tr0, ids, valid, S_MAX)
        logits, cache = model.spec_forward(params, cache, toks[None], pos[None],
                                           rows[None], mask[None])
        lp = jax.nn.log_softmax(logits[0].astype(jnp.float32))
        top_lp, top_tok = jax.lax.top_k(lp, C)
        tr0 = T.insert_children(tr0, ids, valid, rows, top_tok, top_lp)
    plan = T.select_batch(tr0, BS, S_MAX)
    print(f"round {rnd}: tree={int(tr0.n_nodes)} nodes, prefix={int(tr0.plen)} rows, "
          f"verify {int(plan.valid.sum())} nodes: {np.asarray(plan.tokens)[np.asarray(plan.valid)].tolist()}")

    # target side: verify the subgraph
    vlogits, tcache = model.spec_forward(params, tcache, plan.tokens[None],
                                         plan.positions[None], plan.rows[None], plan.mask[None])
    argmax = jnp.argmax(vlogits[0], -1).astype(jnp.int32)
    acc, n_acc, bonus, emitted, n_emit = T.verify_walk(plan.tokens, plan.parent_pos,
                                                       plan.valid, argmax)
    print(f"         accepted {int(n_acc)} + bonus {int(bonus)}: "
          f"emitted {np.asarray(emitted)[:int(n_emit)].tolist()}")

    # re-root + compaction (KV consistency, paper Fig. 5)
    tr0, move, fill = T.reroot(tr0, plan.node_ids, acc, n_acc, bonus)
    n_moves = int(np.asarray(move.mask).sum())
    n_fill = int(np.asarray(fill.mask).sum())
    print(f"         re-rooted: {int(tr0.n_nodes)} survivors, {n_moves} KV moves, "
          f"{n_fill} fill rows, prefix -> {int(tr0.plen)}")

print("\n=== chain-mode rounds on an SSM arch (rwkv6, DESIGN.md §6) ===")
scfg = get_config("rwkv6-7b", smoke=True)
sm = make_model(scfg)
sp = sm.init(jax.random.PRNGKey(0))
sp["lm_head"].value = sp["lm_head"].value * 4.0
eng = ChainSpecEngine(sm, sm, ChainConfig(k=4, mode="parallel", max_new=16), 128, 128)
out, st = eng.session(sp, sp).generate(
    (np.arange(1, 9, dtype=np.int32) % scfg.vocab_size).reshape(1, 8))
print(f"emitted {len(out[0])} tokens in {st.rounds} rounds "
      f"(compression {st.compression_ratio:.2f}, {st.reused_chains} chains reused)")
